//! Fleet orchestration: N data-planes trained as one elastic
//! data-parallel fleet — the paper's replicated-pod training story
//! (days → under two hours) grown onto the PR 2–7 data-plane, plus the
//! heterogeneous-fleet direction of "Reducing Down(stream)time".
//!
//! Three pieces, composed by [`Fleet`]:
//!
//! * [`manifest`] — a **shard manifest** layered on the persist source
//!   fingerprint ([`datasets::persist::SourceFingerprint`]): the dataset
//!   is cut into fixed-length molecule-id shards, and each shard is
//!   deterministically assigned to exactly one fleet member by
//!   rendezvous (highest-random-weight) hashing, so any two hosts that
//!   agree on the fingerprint and the member set derive the *same*
//!   assignment with no coordinator round-trip — and a membership
//!   change moves only the shards whose rendezvous winner changed.
//! * [`membership`] — the **membership/epoch protocol**: members join
//!   and leave mid-run; changes are staged and applied at a
//!   generation flip on an epoch boundary, so an in-flight epoch always
//!   runs under one fixed, numbered generation.
//! * [`scheduler`] — the **overlapped collective schedule**: epoch
//!   `e+1`'s sessions are opened (admission-credited, PR 3) while epoch
//!   `e`'s tail drains and its gradient all-reduce runs, so the planes'
//!   worker pools fill the next epoch's credit windows inside the
//!   collective's shadow instead of idling.
//!
//! # Manifest wire format v1 (little endian)
//!
//! The manifest is derived state — `fingerprint + shard_len + member
//! set` fully determine it — so only those inputs go on the wire. The
//! encoding exists for cross-host exchange (a joiner bootstraps from
//! any member's bytes) and follows the `datasets::persist` conventions:
//! magic + version first, FNV-1a 64 checksum last, decode validates
//! before trusting anything.
//!
//! ```text
//!    0  magic "MPFM" | u16 version = 1 | u16 reserved = 0
//!    8  u64 fp_molecules       -- source fingerprint: molecule count
//!   16  u64 fp_content_hash    -- source fingerprint: sampled hash
//!   24  u32 shard_len          -- molecules per shard (>= 1)
//!   28  u32 n_shards           -- ceil(fp_molecules / shard_len)
//!   32  u64 generation         -- membership generation at encode time
//!   40  u32 n_members
//!   44  members, n_members x 9 bytes each:
//!          u64 member id | u8 state (0 joining, 1 active, 2 draining)
//!    .  u64 checksum           -- FNV-1a 64 over all preceding bytes
//! ```
//!
//! Shard `s` covers molecule ids `[s*shard_len, min((s+1)*shard_len,
//! fp_molecules))`. The owner of shard `s` under member set `M` is
//! `argmax_{m in M} fnv1a64(fp_content_hash ‖ fp_molecules ‖ s ‖ m)`
//! (ties break toward the larger member id). Decode rejects a bad
//! magic/version, a truncated buffer, a member-count/length mismatch,
//! `shard_len = 0`, an `n_shards` that disagrees with the fingerprint,
//! and a checksum mismatch.
//!
//! # Membership state machine
//!
//! ```text
//!            join()                    flip()
//!   (absent) ------->  Joining  ----------------->  Active
//!                         |                           |
//!                         | leave()                   | leave()
//!                         v                           v
//!                      (absent)                    Draining
//!                         ^                           |
//!                         |          flip()           |
//!                         +---------------------------+
//! ```
//!
//! * `join` stages a member as **Joining**: it owns nothing and may
//!   warm its plane (cache restore, arena build) while the current
//!   generation keeps running untouched.
//! * `leave` on an Active member stages it as **Draining**: it keeps
//!   serving its owned shards until the flip. `leave` on a Joining
//!   member just unstages it.
//! * `flip` (epoch boundary only) promotes every Joining member to
//!   **Active**, removes every Draining member, and — iff the active
//!   set changed — bumps the generation and re-derives the assignment.
//!   Warm survivors are *never* rebuilt: rebalance changes which shard
//!   ids a member streams, not its plane, its prepared arena, or its
//!   memoized edge topologies (invariants F1–F3 in the
//!   [`coordinator::dataplane`](crate::coordinator::dataplane) catalog).
//!
//! # Overlap schedule
//!
//! Within one generation, [`Fleet::run_epochs`] pipelines epochs using
//! nothing but session admission credits: epoch `e+1`'s per-member
//! sessions are opened before epoch `e`'s tail is drained, and epoch
//! `e`'s (modeled) gradient all-reduce runs on a side thread while the
//! main thread already drains `e+1`. The worker pools therefore
//! assemble `e+1`'s credit window during exactly the wall time the
//! serial schedule spends blocked on the collective — the schedule the
//! PR 3 credit system was designed to admit. Epoch results (gradient
//! stream fingerprint, weighted-mean gradient) are identical between
//! the serial and overlapped schedules; only the wall clock differs.
//!
//! # Chaos and self-healing
//!
//! Two further modules harden the fleet against the failures pod scale
//! makes routine:
//!
//! * [`faults`] — a seeded, deterministic [`FaultPlan`] (stall, slow
//!   drain, crash, session-open failure, collective failure, damaged
//!   cache) injected through explicit hooks in
//!   [`Fleet::run_epoch_guarded`] and the `DataPlane` session-open
//!   path. No wall-clock randomness anywhere: any schedule replays
//!   bit-for-bit from its seed.
//! * [`watchdog`] — per-member drain progress vs a deadline derived
//!   from the `perfmodel` BSP estimate, on a pure virtual clock, with
//!   exponential backoff on re-probes (invariant F4). A member that
//!   misses its deadline is force-left via a **recovery generation
//!   flip** ([`Membership::force_leave`] — removes only the dead
//!   member, never promotes staged joiners), its unfinished shards are
//!   reassigned to survivors through the rendezvous manifest, and the
//!   epoch completes with the weighted gradient mean still exactly
//!   equal to the single-plane reference over the drained-shard union
//!   (invariant F5). Session-open and collective failures get bounded
//!   retry-with-backoff before escalating to force-leave (invariant
//!   F6). Measured per-member drain rates feed
//!   [`Fleet::reweight_from_rates`], so a chronically slow plane owns
//!   fewer shards next generation ([`ShardManifest::assign_weighted`])
//!   instead of being repeatedly force-left.
//!
//! `molpack fleet --chaos` drives seeded fault schedules end-to-end and
//! asserts the recovery invariants; `make chaos` is the CI entry point.
//!
//! [`datasets::persist::SourceFingerprint`]: crate::datasets::SourceFingerprint

/// Deterministic seeded fault injection (chaos schedules).
pub mod faults;
/// Shard manifest: fingerprint-keyed shards + rendezvous assignment.
pub mod manifest;
/// Membership/epoch protocol: staged joins/leaves, generation flips.
pub mod membership;
/// Multi-plane epoch scheduler with the overlapped collective schedule.
pub mod scheduler;
/// Straggler watchdog: virtual-clock deadlines, probes, drain rates.
pub mod watchdog;

pub use faults::{FaultConfig, FaultEvent, FaultKind, FaultPlan, RecoveryAction};
pub use manifest::{Assignment, MemberId, ShardId, ShardManifest};
pub use membership::{GenerationChange, MemberState, Membership};
pub use scheduler::{
    reference_epoch, Fleet, FleetConfig, FleetEpochReport, GradSketch, GuardedEpochReport,
    RebalanceReport, Schedule,
};
pub use watchdog::{Verdict, Watchdog, WatchdogConfig};
