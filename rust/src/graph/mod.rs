//! Molecular graph substrate: structures, geometric edge construction and
//! the size/sparsity statistics behind the paper's dataset characterization
//! (Fig. 5).

pub mod edges;
pub mod molecule;
pub mod stats;

pub use edges::{knn_edges, radius_edges, EdgeList};
pub use molecule::Molecule;
pub use stats::{degree_stats, graph_sparsity, DatasetProfile};
