//! Dataset characterization (paper Fig. 5): node-count histograms,
//! sparsity-vs-size profiles and degree statistics.

use crate::graph::{radius_edges, Molecule};
use crate::util::stats::{kde, summarize, Summary};

/// Graph "sparsity" as the paper plots it: edge density |E| / (n (n-1)),
/// in [0, 1]. Smaller value = sparser graph.
pub fn graph_sparsity(n_nodes: usize, n_edges: usize) -> f64 {
    if n_nodes < 2 {
        return 0.0;
    }
    n_edges as f64 / (n_nodes as f64 * (n_nodes as f64 - 1.0))
}

/// Degree summary of one molecule's radius graph.
pub fn degree_stats(mol: &Molecule, r_cut: f32) -> Summary {
    let e = radius_edges(mol, r_cut);
    let deg = e.in_degrees(mol.n_atoms());
    summarize(&deg.iter().map(|&d| d as f64).collect::<Vec<_>>())
}

/// Whole-dataset profile: everything needed to regenerate Fig. 5.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: String,
    pub n_graphs: usize,
    /// Node-count histogram: (n_atoms, count).
    pub size_histogram: Vec<(usize, u64)>,
    /// Per-graph (n_atoms, sparsity) scatter, subsampled.
    pub size_vs_sparsity: Vec<(usize, f64)>,
    pub nodes: Summary,
    pub edges: Summary,
    pub sparsity: Summary,
}

impl DatasetProfile {
    /// Profile an iterator of molecules. `r_cut` defines edges (Eq. 1);
    /// `scatter_cap` bounds the retained scatter points.
    pub fn build<I: Iterator<Item = Molecule>>(
        name: &str,
        mols: I,
        r_cut: f32,
        scatter_cap: usize,
    ) -> DatasetProfile {
        let mut hist: std::collections::BTreeMap<usize, u64> = Default::default();
        let mut nodes = Vec::new();
        let mut edges = Vec::new();
        let mut sparsity = Vec::new();
        let mut scatter = Vec::new();
        let mut n_graphs = 0usize;
        for mol in mols {
            let n = mol.n_atoms();
            let e = radius_edges(&mol, r_cut).len();
            *hist.entry(n).or_insert(0) += 1;
            nodes.push(n as f64);
            edges.push(e as f64);
            let s = graph_sparsity(n, e);
            sparsity.push(s);
            if scatter.len() < scatter_cap {
                scatter.push((n, s));
            }
            n_graphs += 1;
        }
        assert!(n_graphs > 0, "empty dataset");
        DatasetProfile {
            name: name.to_string(),
            n_graphs,
            size_histogram: hist.into_iter().collect(),
            size_vs_sparsity: scatter,
            nodes: summarize(&nodes),
            edges: summarize(&edges),
            sparsity: summarize(&sparsity),
        }
    }

    /// The mode of the node-count distribution — the paper uses it to argue
    /// for pack sizes larger than max_nodes (section 5.3.1).
    pub fn mode_nodes(&self) -> usize {
        self.size_histogram
            .iter()
            .max_by_key(|(_, c)| *c)
            .map(|(n, _)| *n)
            .unwrap_or(0)
    }

    pub fn max_nodes(&self) -> usize {
        self.size_histogram.last().map(|(n, _)| *n).unwrap_or(0)
    }

    pub fn min_nodes(&self) -> usize {
        self.size_histogram.first().map(|(n, _)| *n).unwrap_or(0)
    }

    /// KDE of the sparsity distribution on a fixed grid (Fig. 5 bottom).
    pub fn sparsity_kde(&self, grid_points: usize) -> (Vec<f64>, Vec<f64>) {
        let samples: Vec<f64> = self.size_vs_sparsity.iter().map(|&(_, s)| s).collect();
        let grid: Vec<f64> = (0..grid_points)
            .map(|i| i as f64 / (grid_points - 1) as f64)
            .collect();
        let bw = (self.sparsity.std * 0.5).max(0.01);
        let dens = kde(&samples, &grid, bw);
        (grid, dens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn blob_in(seed: u64, n: usize, side: f64) -> Molecule {
        let mut rng = Rng::new(seed);
        let pos = (0..n)
            .map(|_| {
                [
                    rng.uniform(0.0, side) as f32,
                    rng.uniform(0.0, side) as f32,
                    rng.uniform(0.0, side) as f32,
                ]
            })
            .collect();
        Molecule::new(vec![8; n], pos, 0.0)
    }

    fn blob(seed: u64, n: usize) -> Molecule {
        blob_in(seed, n, 6.0)
    }

    #[test]
    fn sparsity_bounds() {
        assert_eq!(graph_sparsity(0, 0), 0.0);
        assert_eq!(graph_sparsity(1, 0), 0.0);
        assert_eq!(graph_sparsity(10, 90), 1.0); // complete digraph
        assert!(graph_sparsity(10, 45) < 1.0);
    }

    #[test]
    fn profile_histogram_counts_sum_to_n_graphs() {
        let mols: Vec<Molecule> = (0..50).map(|s| blob(s, 10 + (s as usize % 5))).collect();
        let p = DatasetProfile::build("test", mols.into_iter(), 3.0, 100);
        assert_eq!(p.n_graphs, 50);
        let total: u64 = p.size_histogram.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 50);
        assert!(p.min_nodes() >= 10 && p.max_nodes() <= 14);
    }

    #[test]
    fn mode_is_most_frequent_size() {
        let mols: Vec<Molecule> = (0..10)
            .map(|s| blob(s, if s < 7 { 12 } else { 20 }))
            .collect();
        let p = DatasetProfile::build("test", mols.into_iter(), 3.0, 100);
        assert_eq!(p.mode_nodes(), 12);
    }

    #[test]
    fn bigger_clusters_are_sparser() {
        // Physical constraint the paper highlights: at fixed *density*
        // (box volume scaling with atom count), the edge fraction falls as
        // size grows because the cutoff ball covers a shrinking share of
        // the cluster.
        let small = blob_in(1, 10, 4.0);
        let large = blob_in(2, 80, 8.0); // same number density (10/4^3 = 80/8^3)
        let es = radius_edges(&small, 3.0).len();
        let el = radius_edges(&large, 3.0).len();
        assert!(
            graph_sparsity(10, es) > graph_sparsity(80, el),
            "expected small cluster denser"
        );
    }

    #[test]
    fn kde_output_has_grid_size() {
        let mols: Vec<Molecule> = (0..20).map(|s| blob(s, 15)).collect();
        let p = DatasetProfile::build("test", mols.into_iter(), 3.0, 100);
        let (grid, dens) = p.sparsity_kde(64);
        assert_eq!(grid.len(), 64);
        assert_eq!(dens.len(), 64);
        assert!(dens.iter().all(|&d| d.is_finite() && d >= 0.0));
    }
}
