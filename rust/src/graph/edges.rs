//! Geometric edge construction (paper Eq. 1): radius graphs via a cell
//! list (O(n) for bounded density) and the KNN variant the paper notes is
//! used in practice to bound edge counts.

use crate::graph::Molecule;

/// Directed edge list in CSR-free COO form.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EdgeList {
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
}

impl EdgeList {
    #[inline]
    pub fn len(&self) -> usize {
        self.src.len()
    }

    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }

    /// In-degree of every node.
    pub fn in_degrees(&self, n_nodes: usize) -> Vec<u32> {
        let mut deg = vec![0u32; n_nodes];
        for &d in &self.dst {
            deg[d as usize] += 1;
        }
        deg
    }
}

/// Cell list over the molecule's bounding box with cell side `r_cut`:
/// neighbor candidates are confined to the 27 surrounding cells.
struct CellList {
    cells: std::collections::HashMap<(i32, i32, i32), Vec<u32>>,
    inv_r: f32,
}

impl CellList {
    fn build(mol: &Molecule, r_cut: f32) -> Self {
        let inv_r = 1.0 / r_cut;
        let mut cells: std::collections::HashMap<_, Vec<u32>> =
            std::collections::HashMap::new();
        for (i, p) in mol.pos.iter().enumerate() {
            let key = (
                (p[0] * inv_r).floor() as i32,
                (p[1] * inv_r).floor() as i32,
                (p[2] * inv_r).floor() as i32,
            );
            cells.entry(key).or_default().push(i as u32);
        }
        CellList { cells, inv_r }
    }

    fn neighbors_of(&self, p: [f32; 3]) -> impl Iterator<Item = u32> + '_ {
        let cx = (p[0] * self.inv_r).floor() as i32;
        let cy = (p[1] * self.inv_r).floor() as i32;
        let cz = (p[2] * self.inv_r).floor() as i32;
        (-1..=1).flat_map(move |dx| {
            (-1..=1).flat_map(move |dy| {
                (-1..=1).flat_map(move |dz| {
                    self.cells
                        .get(&(cx + dx, cy + dy, cz + dz))
                        .into_iter()
                        .flatten()
                        .copied()
                })
            })
        })
    }
}

/// All directed edges (i -> j, i != j) with d_ij < r_cut (paper Eq. 1).
pub fn radius_edges(mol: &Molecule, r_cut: f32) -> EdgeList {
    assert!(r_cut > 0.0);
    let cl = CellList::build(mol, r_cut);
    let mut out = EdgeList::default();
    for i in 0..mol.n_atoms() {
        let mut nbrs: Vec<u32> = cl
            .neighbors_of(mol.pos[i])
            .filter(|&j| j as usize != i && mol.distance(i, j as usize) < r_cut)
            .collect();
        nbrs.sort_unstable(); // determinism independent of hash order
        for j in nbrs {
            out.src.push(i as u32);
            out.dst.push(j);
        }
    }
    out
}

/// K-nearest-neighbor edges within `r_cut`: at most `k` incoming neighbors
/// per node, nearest first — how the paper bounds edge growth ("a fixed
/// number of neighbors for each v").
pub fn knn_edges(mol: &Molecule, r_cut: f32, k: usize) -> EdgeList {
    assert!(r_cut > 0.0 && k > 0);
    let cl = CellList::build(mol, r_cut);
    let mut out = EdgeList::default();
    for i in 0..mol.n_atoms() {
        let mut cand: Vec<(f32, u32)> = cl
            .neighbors_of(mol.pos[i])
            .filter(|&j| j as usize != i)
            .map(|j| (mol.distance(i, j as usize), j))
            .filter(|&(d, _)| d < r_cut)
            .collect();
        cand.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        cand.truncate(k);
        // Edge j -> i carries the message "neighbor j influences i".
        for (_, j) in cand {
            out.src.push(j);
            out.dst.push(i as u32);
        }
    }
    out
}

/// Brute-force O(n^2) radius edges — the oracle for the cell-list path.
pub fn radius_edges_bruteforce(mol: &Molecule, r_cut: f32) -> EdgeList {
    let mut out = EdgeList::default();
    for i in 0..mol.n_atoms() {
        for j in 0..mol.n_atoms() {
            if i != j && mol.distance(i, j) < r_cut {
                out.src.push(i as u32);
                out.dst.push(j as u32);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_molecule(seed: u64, n: usize, side: f64) -> Molecule {
        let mut rng = Rng::new(seed);
        let pos = (0..n)
            .map(|_| {
                [
                    rng.uniform(0.0, side) as f32,
                    rng.uniform(0.0, side) as f32,
                    rng.uniform(0.0, side) as f32,
                ]
            })
            .collect();
        Molecule::new(vec![8; n], pos, 0.0)
    }

    #[test]
    fn cell_list_matches_bruteforce() {
        // Property test over random geometries: the O(n) cell-list result
        // must equal the O(n^2) oracle exactly.
        for seed in 0..20 {
            let mol = random_molecule(seed, 40, 8.0);
            let a = radius_edges(&mol, 3.0);
            let b = radius_edges_bruteforce(&mol, 3.0);
            assert_eq!(a, b, "seed {seed}");
        }
    }

    #[test]
    fn radius_edges_are_symmetric() {
        let mol = random_molecule(7, 30, 6.0);
        let e = radius_edges(&mol, 4.0);
        let set: std::collections::HashSet<(u32, u32)> =
            e.src.iter().zip(&e.dst).map(|(&s, &d)| (s, d)).collect();
        for (&s, &d) in e.src.iter().zip(&e.dst) {
            assert!(set.contains(&(d, s)), "missing reverse of {s}->{d}");
        }
    }

    #[test]
    fn knn_caps_in_degree() {
        let mol = random_molecule(11, 50, 4.0); // dense blob
        let k = 5;
        let e = knn_edges(&mol, 6.0, k);
        let deg = e.in_degrees(mol.n_atoms());
        assert!(deg.iter().all(|&d| d as usize <= k));
        // dense blob: most nodes should hit the cap
        assert!(deg.iter().filter(|&&d| d as usize == k).count() > 40);
    }

    #[test]
    fn knn_selects_nearest() {
        // 1D chain: nearest neighbors of the middle atom are its adjacent
        // atoms.
        let pos = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [2.0, 0.0, 0.0], [5.0, 0.0, 0.0]];
        let mol = Molecule::new(vec![1; 4], pos, 0.0);
        let e = knn_edges(&mol, 10.0, 2);
        // node 1's incoming edges should be from 0 and 2
        let incoming: Vec<u32> = e
            .src
            .iter()
            .zip(&e.dst)
            .filter(|(_, &d)| d == 1)
            .map(|(&s, _)| s)
            .collect();
        assert_eq!(incoming, vec![0, 2]);
    }

    #[test]
    fn no_self_loops() {
        let mol = random_molecule(3, 25, 5.0);
        for e in [radius_edges(&mol, 4.0), knn_edges(&mol, 4.0, 8)] {
            assert!(e.src.iter().zip(&e.dst).all(|(s, d)| s != d));
        }
    }

    #[test]
    fn empty_molecule_has_no_edges() {
        let mol = Molecule::new(vec![], vec![], 0.0);
        assert!(radius_edges(&mol, 3.0).is_empty());
        assert!(knn_edges(&mol, 3.0, 4).is_empty());
    }

    #[test]
    fn edge_count_grows_linearly_for_knn() {
        // KNN bounds edges to k*n even as density grows (paper section 2).
        let mol = random_molecule(5, 100, 5.0);
        let e = knn_edges(&mol, 6.0, 12);
        assert!(e.len() <= 12 * mol.n_atoms());
    }
}
