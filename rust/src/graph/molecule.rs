//! Molecule representation: atomic numbers + 3D coordinates.
//!
//! This is the unit the paper's pipeline moves around: millions of *small*
//! graphs (9–90 atoms for HydroNet, ≤29 for QM9), each with per-node
//! geometry. Edges are derived (Eq. 1), not stored.

/// A single molecule / cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct Molecule {
    /// Atomic numbers (1 = H, 6 = C, 7 = N, 8 = O, ...).
    pub z: Vec<u8>,
    /// Positions in Angstroms, one `[x, y, z]` per atom.
    pub pos: Vec<[f32; 3]>,
    /// Prediction target (e.g. formation energy) in model units.
    pub energy: f32,
}

impl Molecule {
    pub fn new(z: Vec<u8>, pos: Vec<[f32; 3]>, energy: f32) -> Self {
        assert_eq!(z.len(), pos.len(), "z / pos length mismatch");
        Molecule { z, pos, energy }
    }

    #[inline]
    pub fn n_atoms(&self) -> usize {
        self.z.len()
    }

    /// Euclidean distance between atoms `i` and `j`.
    #[inline]
    pub fn distance(&self, i: usize, j: usize) -> f32 {
        let (a, b) = (self.pos[i], self.pos[j]);
        let dx = a[0] - b[0];
        let dy = a[1] - b[1];
        let dz = a[2] - b[2];
        (dx * dx + dy * dy + dz * dz).sqrt()
    }

    /// Geometric center.
    pub fn centroid(&self) -> [f32; 3] {
        let n = self.n_atoms().max(1) as f32;
        let mut c = [0.0f32; 3];
        for p in &self.pos {
            for k in 0..3 {
                c[k] += p[k];
            }
        }
        for v in &mut c {
            *v /= n;
        }
        c
    }

    /// Axis-aligned bounding box (lo, hi).
    pub fn bounds(&self) -> ([f32; 3], [f32; 3]) {
        let mut lo = [f32::INFINITY; 3];
        let mut hi = [f32::NEG_INFINITY; 3];
        for p in &self.pos {
            for k in 0..3 {
                lo[k] = lo[k].min(p[k]);
                hi[k] = hi[k].max(p[k]);
            }
        }
        (lo, hi)
    }

    /// Chemical formula-ish histogram of atomic numbers (for debugging).
    pub fn composition(&self) -> Vec<(u8, usize)> {
        let mut counts = std::collections::BTreeMap::new();
        for &z in &self.z {
            *counts.entry(z).or_insert(0usize) += 1;
        }
        counts.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn water() -> Molecule {
        Molecule::new(
            vec![8, 1, 1],
            vec![[0.0, 0.0, 0.0], [0.96, 0.0, 0.0], [-0.24, 0.93, 0.0]],
            -76.4,
        )
    }

    #[test]
    fn distance_is_symmetric_and_zero_on_diagonal() {
        let m = water();
        assert_eq!(m.distance(0, 0), 0.0);
        assert!((m.distance(0, 1) - 0.96).abs() < 1e-6);
        assert_eq!(m.distance(1, 2), m.distance(2, 1));
    }

    #[test]
    fn centroid_and_bounds() {
        let m = water();
        let c = m.centroid();
        assert!((c[0] - 0.24).abs() < 1e-6);
        let (lo, hi) = m.bounds();
        assert_eq!(lo[0], -0.24);
        assert_eq!(hi[0], 0.96);
    }

    #[test]
    fn composition_counts() {
        assert_eq!(water().composition(), vec![(1, 2), (8, 1)]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        Molecule::new(vec![1, 1], vec![[0.0; 3]], 0.0);
    }
}
