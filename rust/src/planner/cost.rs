//! Cycle-cost model for tile-partitioned gather/scatter — a faithful
//! implementation of the paper's simplified Equations 8 and 9 (which, as
//! the paper notes, "omit many overheads ... and represent more of a
//! theoretical minimum"; we keep their structure and add only the SRAM
//! feasibility check the real planner must also apply).

use crate::ipu::IpuArch;

/// Dimensions of a full gather/scatter op (paper Eqs. 5–6):
/// table A is M×N, indices i ∈ N^I, values V ∈ I×N.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpDims {
    pub i: usize,
    pub m: usize,
    pub n: usize,
}

/// Partition factors for the three dimensions (paper section 4.2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PartitionFactors {
    pub p_i: usize,
    pub p_m: usize,
    pub p_n: usize,
}

impl PartitionFactors {
    pub const UNIT: PartitionFactors = PartitionFactors { p_i: 1, p_m: 1, p_n: 1 };

    pub fn tiles_used(&self) -> usize {
        self.p_i * self.p_m * self.p_n
    }

    /// Per-tile sub-problem sizes I_t, M_t, N_t (ceil division, paper).
    pub fn tile_dims(&self, d: OpDims) -> (usize, usize, usize) {
        (
            d.i.div_ceil(self.p_i),
            d.m.div_ceil(self.p_m),
            d.n.div_ceil(self.p_n),
        )
    }

    /// Per-tile SRAM bytes: table partition + index partition + value
    /// partition (all resident during the op).
    pub fn sram_bytes(&self, d: OpDims, arch: &IpuArch) -> usize {
        let (i_t, m_t, n_t) = self.tile_dims(d);
        let b_data = arch.bytes_data;
        let b_index = arch.bytes_index;
        m_t * n_t * b_data + i_t * b_index + i_t * n_t * b_data
    }

    pub fn fits_sram(&self, d: OpDims, arch: &IpuArch, budget_fraction: f64) -> bool {
        (self.sram_bytes(d, arch) as f64)
            <= budget_fraction * arch.sram_per_tile as f64
    }
}

/// e(b): cycles to send/receive `b` bytes on a tile's exchange port.
#[inline]
fn e(bytes: f64, arch: &IpuArch) -> f64 {
    bytes / arch.exchange_bytes_per_cycle
}

/// g(i, m, n): on-tile gather cycles (paper, under Eq. 8). The W·ceil(i/W)
/// term models round-robin worker scheduling; the fraction models SRAM
/// load/store throughput over the tile's share of the table.
fn g(i: usize, m: usize, n: usize, full_m: usize, arch: &IpuArch) -> f64 {
    let w = arch.worker_threads as f64;
    let num = (n * m * arch.bytes_data) as f64;
    let den = (full_m * arch.bytes_vwidth) as f64;
    w * (i as f64 / w).ceil() * (num / den)
}

/// s(i, m, n): on-tile scatter cycles (paper, under Eq. 9) — workers
/// stride the M dimension, accumulating I×N values.
fn s(i: usize, m: usize, n: usize, full_m: usize, arch: &IpuArch) -> f64 {
    let w = arch.worker_threads as f64;
    let num = (i * n * arch.bytes_data) as f64;
    let den = (full_m * arch.bytes_vwidth) as f64;
    w * (m as f64 / w).ceil() * (num / den)
}

/// Paper Eq. 8: estimated max per-tile cycles for the full gather.
pub fn gather_cost(d: OpDims, p: PartitionFactors, arch: &IpuArch) -> f64 {
    let (i_t, m_t, n_t) = p.tile_dims(d);
    let b_data = arch.bytes_data as f64;
    let b_index = arch.bytes_index as f64;
    let c_partial = e((m_t * n_t) as f64 * b_data, arch)
        + e(i_t as f64 * b_index, arch)
        + g(i_t, m_t, n_t, d.m, arch);
    let c_reduce = if p.p_m > 1 {
        e((i_t * n_t) as f64 * b_data, arch)
            + (i_t * n_t) as f64 * b_data / arch.bytes_vwidth as f64
    } else {
        0.0
    };
    c_partial + c_reduce
}

/// Paper Eq. 9: estimated max per-tile cycles for the full scatter.
pub fn scatter_cost(d: OpDims, p: PartitionFactors, arch: &IpuArch) -> f64 {
    let (i_t, m_t, n_t) = p.tile_dims(d);
    let b_data = arch.bytes_data as f64;
    let b_index = arch.bytes_index as f64;
    let c_partial = e((i_t * n_t) as f64 * b_data, arch)
        + e(i_t as f64 * b_index, arch)
        + s(i_t, m_t, n_t, d.m, arch);
    // The paper prints `P_I > 0`, which is always true; the reduction is
    // only needed when the I dimension is actually split (partials from
    // P_I tiles must be combined), so we use P_I > 1.
    let c_reduce = if p.p_i > 1 {
        e((m_t * n_t) as f64 * b_data, arch)
            + (m_t * n_t) as f64 * b_data / arch.bytes_vwidth as f64
    } else {
        0.0
    };
    c_partial + c_reduce
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ipu::IpuArch;

    fn dims() -> OpDims {
        // one interaction block's gather at our default batch geometry
        OpDims { i: 4608, m: 384, n: 64 }
    }

    #[test]
    fn unit_partition_uses_one_tile() {
        let p = PartitionFactors::UNIT;
        assert_eq!(p.tiles_used(), 1);
        assert_eq!(p.tile_dims(dims()), (4608, 384, 64));
    }

    #[test]
    fn ceil_partitioning() {
        let p = PartitionFactors { p_i: 100, p_m: 7, p_n: 3 };
        let (i_t, m_t, n_t) = p.tile_dims(dims());
        assert_eq!(i_t, 47); // ceil(4608/100)
        assert_eq!(m_t, 55); // ceil(384/7)
        assert_eq!(n_t, 22); // ceil(64/3)
    }

    #[test]
    fn splitting_i_reduces_gather_cost() {
        let arch = IpuArch::bow();
        let d = dims();
        let c1 = gather_cost(d, PartitionFactors::UNIT, &arch);
        let c8 = gather_cost(d, PartitionFactors { p_i: 8, p_m: 1, p_n: 1 }, &arch);
        assert!(c8 < c1, "c8={c8} c1={c1}");
    }

    #[test]
    fn splitting_m_triggers_gather_reduce_term() {
        let arch = IpuArch::bow();
        let d = dims();
        let p1 = PartitionFactors { p_i: 4, p_m: 1, p_n: 1 };
        let p2 = PartitionFactors { p_i: 4, p_m: 2, p_n: 1 };
        // with p_m > 1 a reduction term appears; cost model must include it
        let base = gather_cost(d, p1, &arch);
        let split = gather_cost(d, p2, &arch);
        // the M split halves table traffic but adds the reduce: both
        // finite, and the delta must be smaller than the naive halving
        assert!(split > base / 2.0);
    }

    #[test]
    fn scatter_reduce_only_when_i_split() {
        let arch = IpuArch::bow();
        let d = dims();
        let no_split = scatter_cost(d, PartitionFactors { p_i: 1, p_m: 4, p_n: 1 }, &arch);
        let with_split = scatter_cost(d, PartitionFactors { p_i: 2, p_m: 4, p_n: 1 }, &arch);
        // exact values differ; the i-split adds a reduce term over M_t N_t
        assert!(no_split.is_finite() && with_split.is_finite());
        assert!(with_split > 0.0 && no_split > 0.0);
    }

    #[test]
    fn sram_accounting_scales_down_with_partitioning() {
        let arch = IpuArch::bow();
        let d = dims();
        let unit = PartitionFactors::UNIT.sram_bytes(d, &arch);
        let split = PartitionFactors { p_i: 8, p_m: 8, p_n: 2 }.sram_bytes(d, &arch);
        assert!(split < unit / 12);
        // the unsplit op cannot fit a single tile's SRAM
        assert!(!PartitionFactors::UNIT.fits_sram(d, &arch, 0.8));
    }

    #[test]
    fn costs_monotone_in_problem_size() {
        let arch = IpuArch::bow();
        let p = PartitionFactors { p_i: 16, p_m: 4, p_n: 1 };
        let small = OpDims { i: 1024, m: 128, n: 32 };
        let big = OpDims { i: 4096, m: 512, n: 64 };
        assert!(gather_cost(small, p, &arch) < gather_cost(big, p, &arch));
        assert!(scatter_cost(small, p, &arch) < scatter_cost(big, p, &arch));
    }
}
