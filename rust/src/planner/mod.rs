//! The scatter/gather planner (paper section 4.2.2).
//!
//! Gather/scatter dominate message passing; on a tiled machine their cost
//! depends on how the (I, M, N) iteration space is partitioned across
//! tiles. The planner minimizes the paper's cycle-cost model (Eqs. 8–9) by
//! exhaustive search over partition factors (P_I, P_M, P_N), subject to
//! per-tile SRAM capacity.

pub mod cost;
pub mod search;

pub use cost::{gather_cost, scatter_cost, OpDims, PartitionFactors};
pub use search::{plan_gather, plan_scatter, Plan};
