//! Exhaustive plan search (paper: "a minimum is found by exhaustive search
//! of valid implementation parameter settings").
//!
//! Candidate partition factors per dimension are 1, 2, 3, ... up to the
//! dimension size, thinned to divisor-like values so the search space
//! stays ~10^4 while covering every distinct ceil-partition shape that
//! matters. Validity: tiles_used ≤ tile count and the per-tile working set
//! fits the SRAM budget.

use super::cost::{gather_cost, scatter_cost, OpDims, PartitionFactors};
use crate::ipu::IpuArch;

/// The planner's output for one operation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plan {
    pub factors: PartitionFactors,
    pub cycles: f64,
    pub sram_bytes: usize,
}

/// Candidate factors for one dimension: every value in [1, 16], then
/// geometrically spaced values up to min(dim, tiles). Distinct ceil
/// partitions repeat heavily above 16, so this loses nothing measurable.
fn candidates(dim: usize, tiles: usize) -> Vec<usize> {
    let hi = dim.min(tiles).max(1);
    let mut out: Vec<usize> = (1..=hi.min(16)).collect();
    let mut v = 16usize;
    while v < hi {
        v = (v * 3) / 2;
        out.push(v.min(hi));
    }
    out.dedup();
    out
}

/// Fraction of tile SRAM the planner may budget for one op's operands.
const SRAM_BUDGET: f64 = 0.5;

fn search(
    d: OpDims,
    arch: &IpuArch,
    cost: impl Fn(OpDims, PartitionFactors, &IpuArch) -> f64,
) -> Plan {
    let mut best: Option<Plan> = None;
    let mut fallback: Option<Plan> = None; // min-SRAM plan if none fits
    for &p_i in &candidates(d.i, arch.tiles) {
        for &p_m in &candidates(d.m, arch.tiles) {
            if p_i * p_m > arch.tiles {
                break;
            }
            for &p_n in &candidates(d.n, arch.tiles) {
                let f = PartitionFactors { p_i, p_m, p_n };
                if f.tiles_used() > arch.tiles {
                    break;
                }
                let sram = f.sram_bytes(d, arch);
                let plan = Plan { factors: f, cycles: cost(d, f, arch), sram_bytes: sram };
                if (sram as f64) <= SRAM_BUDGET * arch.sram_per_tile as f64 {
                    if best.map_or(true, |b| plan.cycles < b.cycles) {
                        best = Some(plan);
                    }
                } else if fallback.map_or(true, |fb| sram < fb.sram_bytes) {
                    fallback = Some(plan);
                }
            }
        }
    }
    best.or(fallback).expect("search space non-empty")
}

/// Plan the gather(A[M,N], i[I]) op (paper Eq. 8).
pub fn plan_gather(d: OpDims, arch: &IpuArch) -> Plan {
    search(d, arch, gather_cost)
}

/// Plan the scatter(A[M,N], i[I], V[I,N]) op (paper Eq. 9).
pub fn plan_scatter(d: OpDims, arch: &IpuArch) -> Plan {
    search(d, arch, scatter_cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn arch() -> IpuArch {
        IpuArch::bow()
    }

    fn schnet_dims() -> OpDims {
        OpDims { i: 4608, m: 384, n: 64 }
    }

    #[test]
    fn plan_beats_unit_partition() {
        let d = schnet_dims();
        let a = arch();
        let plan = plan_gather(d, &a);
        let unit = gather_cost(d, PartitionFactors::UNIT, &a);
        assert!(
            plan.cycles < unit / 4.0,
            "planned {} vs unit {unit}",
            plan.cycles
        );
    }

    #[test]
    fn plan_respects_tile_budget_and_sram() {
        let d = schnet_dims();
        let a = arch();
        for plan in [plan_gather(d, &a), plan_scatter(d, &a)] {
            assert!(plan.factors.tiles_used() <= a.tiles);
            assert!((plan.sram_bytes as f64) <= 0.5 * a.sram_per_tile as f64);
        }
    }

    #[test]
    fn plan_is_optimal_within_candidates() {
        // no candidate combination beats the returned plan
        let d = OpDims { i: 512, m: 128, n: 32 };
        let a = arch();
        let plan = plan_gather(d, &a);
        for p_i in 1..=32usize {
            for p_m in 1..=16usize {
                for p_n in 1..=8usize {
                    let f = PartitionFactors { p_i, p_m, p_n };
                    if f.tiles_used() > a.tiles
                        || !f.fits_sram(d, &a, 0.5)
                    {
                        continue;
                    }
                    assert!(
                        plan.cycles <= gather_cost(d, f, &a) + 1e-9,
                        "beaten by {f:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn planner_finds_sweet_spot_not_extremes() {
        // The paper's point: neither serialize (1 tile) nor shard to all
        // 1472 tiles — there is a middle optimum once exchange costs bite.
        let d = schnet_dims();
        let a = arch();
        let plan = plan_scatter(d, &a);
        assert!(plan.factors.tiles_used() > 1, "should parallelize");
        let max_split = PartitionFactors { p_i: 16, p_m: 12, p_n: 7 };
        assert!(max_split.tiles_used() <= a.tiles);
        let shattered = scatter_cost(d, max_split, &a);
        assert!(plan.cycles <= shattered);
    }

    #[test]
    fn tiny_ops_prefer_few_tiles() {
        // a tiny op can never use more tiles than it has elements to split
        let d = OpDims { i: 8, m: 8, n: 4 };
        let plan = plan_gather(d, &arch());
        assert!(plan.factors.tiles_used() <= 8 * 8 * 4);
    }

    #[test]
    fn property_plans_always_valid() {
        let a = arch();
        check(60, |rng| {
            let d = OpDims {
                i: rng.range(1, 10_000),
                m: rng.range(1, 2_000),
                n: rng.range(1, 256),
            };
            for plan in [plan_gather(d, &a), plan_scatter(d, &a)] {
                assert!(plan.cycles.is_finite() && plan.cycles > 0.0);
                assert!(plan.factors.tiles_used() <= a.tiles);
                let (i_t, m_t, n_t) = plan.factors.tile_dims(d);
                assert!(i_t >= 1 && m_t >= 1 && n_t >= 1);
            }
        });
    }

    #[test]
    fn bigger_feature_dim_costs_more() {
        let a = arch();
        let small = plan_gather(OpDims { i: 4096, m: 512, n: 32 }, &a);
        let large = plan_gather(OpDims { i: 4096, m: 512, n: 128 }, &a);
        assert!(large.cycles > small.cycles);
    }
}
