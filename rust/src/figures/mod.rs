//! Figure/table regeneration harness: one function per paper exhibit
//! (Figs. 5–13, Table 1), each returning a self-contained text report
//! (markdown tables + ASCII quick-look plots) recorded in EXPERIMENTS.md.
//!
//! Figs. 5 and 8 run the *real* dataset generators and the *real* LPFHP
//! packer; Fig. 12 runs the BSP simulator; Fig. 11 is produced by the real
//! PJRT training run (`examples/train_hydronet.rs`); the remaining
//! exhibits evaluate the calibrated performance model (DESIGN.md §2).

use crate::baseline::{estimate_gpu_epoch, GpuArch};
use crate::datasets::PaperDataset;
use crate::graph::DatasetProfile;
use crate::ipu::{simulate_weight_update_tail_curve, IpuArch};
use crate::perfmodel::calibration::{paper_profiles, PAPER_TABLE1};
use crate::perfmodel::{estimate_epoch, OptFlags, SchNetDims, TrainSetup};
use crate::util::plot::{bar_chart, line_chart, md_table};

/// Sample size for dataset-level measurements (keeps figures fast while
/// the full datasets are millions of graphs).
const SAMPLE: usize = 4000;

fn setup(n_ipus: usize, opts: OptFlags) -> TrainSetup {
    TrainSetup { n_ipus, opts, ..Default::default() }
}

/// Fig. 5: dataset characterization — node-count histograms and sparsity
/// KDE for HydroNet and QM9.
pub fn fig5() -> String {
    let mut out = String::from("## Figure 5 — dataset characterization\n\n");
    for (ds, r_cut) in [(PaperDataset::Qm9, 6.0f32), (PaperDataset::Water4_5m, 6.0)] {
        let src = ds.source(ds.full_len() / 1500, 5);
        let profile = DatasetProfile::build(
            ds.name(),
            (0..src.len().min(1500)).map(|i| src.get(i)),
            r_cut,
            1500,
        );
        out.push_str(&format!(
            "### {} — {} graphs sampled\n\nnodes: min {} / mode {} / max {} (mean {:.1})\n\
             sparsity: mean {:.3} (p50 {:.3})\n\n",
            profile.name,
            profile.n_graphs,
            profile.min_nodes(),
            profile.mode_nodes(),
            profile.max_nodes(),
            profile.nodes.mean,
            profile.sparsity.mean,
            profile.sparsity.p50,
        ));
        // node histogram as bars (10 bins)
        let maxn = profile.max_nodes() as f64;
        let mut bins = vec![0u64; 10];
        for &(n, c) in &profile.size_histogram {
            let b = (((n as f64) / (maxn + 1.0)) * 10.0) as usize;
            bins[b.min(9)] += c;
        }
        let rows: Vec<(String, f64)> = bins
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                (
                    format!("{}-{}", (i as f64 * maxn / 10.0) as usize, ((i + 1) as f64 * maxn / 10.0) as usize),
                    c as f64,
                )
            })
            .collect();
        out.push_str(&bar_chart("node-count histogram", &rows, 40));
        let (grid, dens) = profile.sparsity_kde(48);
        out.push_str(&line_chart(
            "sparsity KDE (|E| / n(n-1))",
            &grid,
            &[("density", dens)],
            48,
            10,
        ));
        out.push('\n');
    }
    out.push_str(
        "Shape check vs paper: QM9 small+dense (sparsity mass near 1.0), HydroNet \
         wide size range with sparsity falling as clusters grow; HydroNet mode above \
         half the max size.\n",
    );
    out
}

/// Fig. 6: progressive optimization speedups at 16 IPUs.
pub fn fig6() -> String {
    let arch = IpuArch::bow();
    let mut out = String::from(
        "## Figure 6 — speedup of progressive optimizations (16 IPUs, vs no-opt baseline)\n\n",
    );
    let mut rows = Vec::new();
    for w in paper_profiles() {
        let base = estimate_epoch(&w, &setup(16, OptFlags::NONE), &arch).epoch_secs;
        let mut row = vec![w.name.clone()];
        for (_, opts) in OptFlags::progression() {
            let e = estimate_epoch(&w, &setup(16, opts), &arch).epoch_secs;
            row.push(format!("{:.2}x", base / e));
        }
        rows.push(row);
    }
    let headers = ["dataset", "Packing", "+Async I/O", "+Opt softplus", "+Merged AR", "+Prefetch"];
    out.push_str(&md_table(&headers, &rows));
    out.push_str(
        "\nShape check vs paper: packing alone is worth up to ~25%, each further \
         optimization adds; prefetch helps 4.5M but regresses QM9.\n",
    );
    out
}

/// Fig. 7: packing-over-padding (a) and async-over-sync (b) vs scale.
pub fn fig7() -> String {
    let arch = IpuArch::bow();
    let scales = [4usize, 8, 16, 32, 64];
    let mut out = String::from("## Figure 7 — optimization impact vs #IPUs\n\n");
    let variants: [(&str, fn(&mut OptFlags)); 2] = [
        ("(a) packing over padding", |f| f.packing = false),
        ("(b) async I/O over sync dataloader", |f| f.async_io = false),
    ];
    for (title, flip) in variants {
        let mut rows = Vec::new();
        for w in paper_profiles() {
            let mut row = vec![w.name.clone()];
            for &r in &scales {
                let on = estimate_epoch(&w, &setup(r, OptFlags::ALL), &arch).epoch_secs;
                let mut off_flags = OptFlags::ALL;
                flip(&mut off_flags);
                let off = estimate_epoch(&w, &setup(r, off_flags), &arch).epoch_secs;
                row.push(format!("{:.2}x", off / on));
            }
            rows.push(row);
        }
        out.push_str(&format!("### {title}\n\n"));
        out.push_str(&md_table(&["dataset", "4", "8", "16", "32", "64"], &rows));
        out.push('\n');
    }
    out.push_str(
        "Shape check vs paper: packing's advantage grows with scale and is larger \
         for QM9 (denser, smaller graphs); async I/O speedup is present at every scale.\n",
    );
    out
}

/// Fig. 8: packing efficiency vs max pack size — real LPFHP on real size
/// columns, including the non-smooth spikes.
pub fn fig8() -> String {
    let mut out = String::from(
        "## Figure 8 — packing efficiency vs pack size s_m (real LPFHP runs)\n\n\
         metric: % of the padding-baseline waste eliminated by LPFHP\n\n",
    );
    for ds in [PaperDataset::Qm9, PaperDataset::Water2_7m, PaperDataset::Water4_5m] {
        let src = ds.source((ds.full_len() / SAMPLE).max(1), 7);
        let n = src.len().min(SAMPLE);
        let sizes: Vec<usize> = (0..n).map(|i| src.n_atoms(i)).collect();
        let max = *sizes.iter().max().unwrap();
        let total: usize = sizes.iter().sum();
        let pad_waste = 1.0 - total as f64 / (sizes.len() * max) as f64;

        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut rows = Vec::new();
        let mut s_m = max;
        while s_m <= 8 * max {
            let p = crate::packing::lpfhp(&sizes, s_m, None);
            let waste = p.padding_fraction();
            let reduced = 100.0 * (pad_waste - waste) / pad_waste;
            xs.push(s_m as f64);
            ys.push(waste * 100.0);
            rows.push(vec![
                s_m.to_string(),
                format!("{:.1}%", waste * 100.0),
                format!("{:.1}%", reduced),
            ]);
            s_m += (max / 4).max(1);
        }
        out.push_str(&format!(
            "### {} (padding baseline wastes {:.1}%)\n\n",
            ds.name(),
            pad_waste * 100.0
        ));
        out.push_str(&md_table(&["s_m", "LPFHP padding", "waste reduced"], &rows));
        out.push_str(&line_chart(
            "residual padding % vs s_m",
            &xs,
            &[("padding%", ys)],
            48,
            10,
        ));
        out.push('\n');
    }
    out.push_str(
        "Shape check vs paper: padding wastes ~38% on QM9; LPFHP at s_m = max helps \
         but larger s_m drives residual padding toward ~2%, non-monotonically (spikes \
         from the discrete size histogram).\n",
    );
    out
}

/// Fig. 9: strong-scaling throughput, packing vs padding.
pub fn fig9() -> String {
    let arch = IpuArch::bow();
    let scales = [1usize, 2, 4, 8, 16, 32, 64];
    let mut out = String::from("## Figure 9 — strong scaling throughput (graphs/s)\n\n");
    let mut rows = Vec::new();
    for w in paper_profiles() {
        for (label, packing) in [("packing", true), ("padding", false)] {
            let mut row = vec![format!("{} ({label})", w.name)];
            for &r in &scales {
                let mut opts = OptFlags::ALL;
                opts.packing = packing;
                let e = estimate_epoch(&w, &setup(r, opts), &arch);
                row.push(format!("{:.0}", e.throughput_graphs_per_s));
            }
            rows.push(row);
        }
    }
    out.push_str(&md_table(&["dataset", "1", "2", "4", "8", "16", "32", "64"], &rows));
    out.push_str(
        "\nShape check vs paper: QM9 throughput peaks at 16-32 IPUs then falls; \
         2.7M/4.5M keep scaling through 64; packing above padding everywhere.\n",
    );
    out
}

/// Fig. 10: per-epoch time vs embedding size × #interaction blocks.
pub fn fig10() -> String {
    let arch = IpuArch::bow();
    let mut out =
        String::from("## Figure 10 — per-epoch seconds vs (embedding, #blocks), 16 IPUs\n\n");
    for w in paper_profiles() {
        let mut rows = Vec::new();
        for hidden in [64usize, 128, 256, 512] {
            let mut row = vec![hidden.to_string()];
            for blocks in [2usize, 4, 6] {
                let mut s = setup(16, OptFlags::ALL);
                s.model = SchNetDims { hidden, n_rbf: 25, n_interactions: blocks };
                let e = estimate_epoch(&w, &s, &arch);
                row.push(format!("{:.2}", e.epoch_secs));
            }
            rows.push(row);
        }
        out.push_str(&format!("### {}\n\n", w.name));
        out.push_str(&md_table(&["embed \\ blocks", "2", "4", "6"], &rows));
        out.push('\n');
    }
    out.push_str(
        "Shape check vs paper: time grows with embedding size and block count \
         (matmul-dominated); small configs are overhead-dominated and nearly flat.\n",
    );
    out
}

/// Fig. 11 analogue: produced by the real training run; this function
/// reports where to find it.
pub fn fig11() -> String {
    "## Figure 11 — per-epoch MSE loss (REAL training run)\n\n\
     Regenerate with: `cargo run --release --example train_hydronet`\n\
     The example trains the actual AOT-compiled SchNet on synthetic \
     HydroNet data through the PJRT runtime and prints the loss curve; \
     the latest run is recorded in EXPERIMENTS.md.\n"
        .to_string()
}

/// Fig. 12: tile busy-fraction timelines, merged vs per-tensor all-reduce
/// (BSP simulator).
pub fn fig12() -> String {
    let mut out = String::from(
        "## Figure 12 — tile utilization during weight update (BSP sim, 256 tiles)\n\n",
    );
    let (t_merged, merged_curve, util_m) = simulate_weight_update_tail_curve(true);
    let (t_unmerged, unmerged_curve, util_u) = simulate_weight_update_tail_curve(false);
    out.push_str(&format!(
        "makespan: merged {:.0} us vs per-tensor {:.0} us; utilization {:.0}% vs {:.0}%\n\n",
        t_merged * 1e6,
        t_unmerged * 1e6,
        util_m * 100.0,
        util_u * 100.0
    ));
    let x: Vec<f64> = (0..merged_curve.len()).map(|i| i as f64).collect();
    out.push_str(&line_chart(
        "busy tile fraction over time (o merged, x per-tensor)",
        &x,
        &[("merged", merged_curve), ("per-tensor", unmerged_curve)],
        60,
        12,
    ));
    out.push_str(
        "\nShape check vs paper: without merging, the tail shows long stretches where \
         only a fraction of tiles are engaged; merging keeps tiles busy to the end.\n",
    );
    out
}

/// Table 1 (and Fig. 13): per-epoch seconds per dataset × #IPUs × 8 GPUs,
/// with the paper's numbers side by side.
pub fn table1() -> String {
    let ipu = IpuArch::bow();
    let gpu = GpuArch::a100();
    let model = SchNetDims::default();
    let mut out = String::from("## Table 1 / Figure 13 — average per-epoch seconds\n\n");
    let mut rows = Vec::new();
    for (w, (name, paper_ipu, paper_gpu)) in paper_profiles().iter().zip(PAPER_TABLE1.iter()) {
        let mut row = vec![name.to_string()];
        for (ci, r) in [8usize, 16, 32, 64].iter().enumerate() {
            let e = estimate_epoch(w, &setup(*r, OptFlags::ALL), &ipu);
            row.push(format!("{:.2} ({:.2})", e.epoch_secs, paper_ipu[ci]));
        }
        let g = estimate_gpu_epoch(w, &model, 8, &gpu);
        row.push(format!("{:.2} ({:.2})", g.epoch_secs, paper_gpu));
        let e16 = estimate_epoch(w, &setup(16, OptFlags::ALL), &ipu);
        row.push(format!(
            "{:.2}x ({:.2}x)",
            g.epoch_secs / e16.epoch_secs,
            paper_gpu / paper_ipu[1]
        ));
        rows.push(row);
    }
    out.push_str(&md_table(
        &["dataset", "8 IPU", "16 IPU", "32 IPU", "64 IPU", "8 GPU", "16IPU/8GPU speedup"],
        &rows,
    ));
    out.push_str("\nEntries are `model (paper)`.\n");
    out
}

/// Everything, in paper order.
pub fn all() -> String {
    [fig5(), fig6(), fig7(), fig8(), fig9(), fig10(), fig11(), fig12(), table1()].join("\n---\n\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_reports_all_datasets_and_opts() {
        let s = fig6();
        for name in ["QM9", "500K", "2.7M", "4.5M", "Prefetch", "Packing"] {
            assert!(s.contains(name), "missing {name}");
        }
    }

    #[test]
    fn fig8_uses_real_packer_output() {
        let s = fig8();
        assert!(s.contains("padding baseline wastes"));
        assert!(s.contains("%"));
    }

    #[test]
    fn fig12_merged_wins() {
        let s = fig12();
        assert!(s.contains("makespan"));
    }

    #[test]
    fn table1_has_paper_reference_numbers() {
        let s = table1();
        assert!(s.contains("(0.72)"), "paper QM9@16 missing:\n{s}");
        assert!(s.contains("(60.00)") || s.contains("(60)"), "paper GPU 4.5M missing");
    }
}
