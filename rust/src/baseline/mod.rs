//! GPU baseline model: the out-of-the-box PyG SchNet on 8×A100 with
//! PyTorch DDP (paper section 5.7, the Table 1 "8GPUs" column).
//!
//! The model captures *why* the unoptimized GPU path loses on this
//! workload class, per the paper's own analysis (appendix A.2.1 and the
//! Hosseini et al. profiling it cites): memory-bound gather/scatter,
//! per-kernel launch overhead multiplied by many small ops, padding waste
//! in node-wise compute, NCCL all-reduce, and a Python dataloader on the
//! host. Constants are A100 datasheet numbers with utilization factors
//! typical for PyG message passing.

use crate::perfmodel::{SchNetDims, WorkloadProfile};

/// A100 SXM4 40GB + host, DDP over NVLink/NCCL.
#[derive(Debug, Clone, Copy)]
pub struct GpuArch {
    /// Usable f32 FLOP/s (CUDA cores; PyG SchNet runs f32, no tensor cores
    /// for the scatter-heavy path).
    pub flops: f64,
    /// HBM bandwidth bytes/s.
    pub hbm_bps: f64,
    /// Achievable fraction of HBM bandwidth for gather/scatter kernels.
    pub scatter_bw_util: f64,
    /// Dense matmul utilization for small GNN GEMMs.
    pub matmul_util: f64,
    /// CUDA kernel launch + framework dispatch overhead per op, seconds.
    pub launch_overhead_s: f64,
    /// NCCL all-reduce: per-call latency and per-direction bus bandwidth.
    pub nccl_latency_s: f64,
    pub nccl_bus_bps: f64,
    /// Python dataloader cost per graph on the host, seconds.
    pub host_prep_per_graph_s: f64,
    /// DDP prepares batches with multiple workers.
    pub loader_workers: usize,
}

impl GpuArch {
    pub fn a100() -> GpuArch {
        GpuArch {
            flops: 19.5e12,
            hbm_bps: 1.555e12,
            scatter_bw_util: 0.70,
            matmul_util: 0.50,
            launch_overhead_s: 25e-6,
            nccl_latency_s: 25e-6,
            nccl_bus_bps: 150e9,
            host_prep_per_graph_s: 55e-6,
            loader_workers: 4,
        }
    }
}

/// Per-epoch estimate for DDP training on `n_gpus` GPUs.
#[derive(Debug, Clone, Copy)]
pub struct GpuEpochEstimate {
    pub epoch_secs: f64,
    pub throughput_graphs_per_s: f64,
    pub step_secs: f64,
    pub steps_per_epoch: f64,
}

/// Graphs per device batch in the out-of-the-box PyG loader.
const GRAPHS_PER_BATCH: f64 = 128.0;

/// Small-kernel efficiency: GEMMs/scatters over few edges underutilize an
/// A100 (wave quantization + launch-bound tails). Scales the achievable
/// utilization by problem size — the key reason the paper's QM9 speedup
/// (2.58x) exceeds the water-cluster ones (1.28-1.71x).
fn size_efficiency(edges: f64) -> f64 {
    (edges / 100_000.0).clamp(0.25, 1.0)
}

pub fn estimate_gpu_epoch(
    w: &WorkloadProfile,
    model: &SchNetDims,
    n_gpus: usize,
    gpu: &GpuArch,
) -> GpuEpochEstimate {
    let f = model.hidden as f64;
    let k = model.n_rbf as f64;
    let t_blocks = model.n_interactions as f64;
    let g = GRAPHS_PER_BATCH;

    // PyG batches concatenate graphs without fixed shapes (dynamic), so
    // compute follows *real* sizes — the GPU pays no padding flops, but
    // pays dispatch overhead for every one of the many small kernels.
    let nodes = g * w.avg_nodes;
    let edges = nodes * w.avg_degree;

    // Dense work (fwd + bwd ≈ 3x).
    let edge_flops = edges * 2.0 * (k * f + f * f + 3.0 * f) * t_blocks * 3.0;
    let node_flops = nodes * 2.0 * (3.0 * f * f) * t_blocks * 3.0 + nodes * 2.0 * f * (f / 2.0) * 3.0;
    let eff = size_efficiency(edges);
    let matmul_secs = (edge_flops + node_flops) / (gpu.flops * gpu.matmul_util * eff);

    // Gather + scatter are HBM-bound: each moves ~3 × E × F × 4 bytes
    // (read source rows, read/write destination) per direction per block.
    let gs_bytes = 3.0 * edges * f * 4.0 * t_blocks * 2.0 * 2.0; // ops × fwd+bwd
    let gs_secs = gs_bytes / (gpu.hbm_bps * gpu.scatter_bw_util * eff);

    // Kernel launches: PyG SchNet issues ~25 ops per interaction block
    // plus ~40 for embedding/readout/optimizer, fwd + bwd.
    let n_kernels = (25.0 * t_blocks + 40.0) * 2.0;
    let launch_secs = n_kernels * gpu.launch_overhead_s;

    let step_compute = matmul_secs + gs_secs + launch_secs;

    // DDP all-reduce (ring over NVLink) once per step.
    let grad_bytes = 4.0 * model.param_count() as f64;
    let r = n_gpus as f64;
    let allreduce = if n_gpus > 1 {
        gpu.nccl_latency_s * (1.0 + r.log2()) + 2.0 * (r - 1.0) / r * grad_bytes / gpu.nccl_bus_bps
    } else {
        0.0
    };

    // Host dataloader (per replica, workers overlap with compute).
    let host = g * gpu.host_prep_per_graph_s / gpu.loader_workers as f64;

    let step_secs = (step_compute + allreduce).max(host) + 0.1 * host;
    let steps = (w.n_graphs as f64 / (g * r)).ceil();
    let epoch_secs = steps * step_secs + 0.5; // CUDA context + epoch setup
    GpuEpochEstimate {
        epoch_secs,
        throughput_graphs_per_s: w.n_graphs as f64 / epoch_secs,
        step_secs,
        steps_per_epoch: steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ipu::IpuArch;
    use crate::perfmodel::{estimate_epoch, OptFlags, TrainSetup};

    fn qm9() -> WorkloadProfile {
        WorkloadProfile {
            name: "QM9".into(),
            n_graphs: 134_000,
            avg_nodes: 18.0,
            max_nodes: 29,
            avg_degree: 12.0,
            packing_efficiency: 0.98,
        }
    }

    fn water(n: usize, avg: f64, max: usize) -> WorkloadProfile {
        WorkloadProfile {
            name: "water".into(),
            n_graphs: n,
            avg_nodes: avg,
            max_nodes: max,
            avg_degree: 14.0,
            packing_efficiency: 0.97,
        }
    }

    #[test]
    fn sixteen_ipus_beat_eight_gpus() {
        // Table 1's headline: 16 IPUs vs 8 A100s, speedup 1.28-2.58x.
        let ipu = IpuArch::bow();
        let gpu = GpuArch::a100();
        let model = SchNetDims::default();
        for w in [qm9(), water(4_500_000, 60.0, 90)] {
            let i = estimate_epoch(
                &w,
                &TrainSetup { n_ipus: 16, opts: OptFlags::ALL, ..Default::default() },
                &ipu,
            );
            let g = estimate_gpu_epoch(&w, &model, 8, &gpu);
            let speedup = g.epoch_secs / i.epoch_secs;
            assert!(
                (1.05..=4.0).contains(&speedup),
                "{}: speedup {speedup} (ipu {} vs gpu {})",
                w.name,
                i.epoch_secs,
                g.epoch_secs
            );
        }
    }

    #[test]
    fn qm9_speedup_exceeds_water_speedup() {
        // Paper: 2.58x on QM9 vs 1.71x on 4.5M — small dense graphs hurt
        // the GPU (launch overhead per tiny kernel) more than big ones.
        let ipu = IpuArch::bow();
        let gpu = GpuArch::a100();
        let model = SchNetDims::default();
        let s = |w: &WorkloadProfile| {
            let i = estimate_epoch(
                w,
                &TrainSetup { n_ipus: 16, opts: OptFlags::ALL, ..Default::default() },
                &ipu,
            );
            estimate_gpu_epoch(w, &model, 8, &gpu).epoch_secs / i.epoch_secs
        };
        assert!(s(&qm9()) > s(&water(4_500_000, 60.0, 90)));
    }

    #[test]
    fn gpu_epoch_scales_with_dataset_size() {
        let gpu = GpuArch::a100();
        let model = SchNetDims::default();
        let small = estimate_gpu_epoch(&water(500_000, 45.0, 75), &model, 8, &gpu);
        let big = estimate_gpu_epoch(&water(4_500_000, 60.0, 90), &model, 8, &gpu);
        assert!(big.epoch_secs > 4.0 * small.epoch_secs);
    }

    #[test]
    fn more_gpus_reduce_epoch_time() {
        let gpu = GpuArch::a100();
        let model = SchNetDims::default();
        let w = water(4_500_000, 60.0, 90);
        let one = estimate_gpu_epoch(&w, &model, 1, &gpu);
        let eight = estimate_gpu_epoch(&w, &model, 8, &gpu);
        assert!(eight.epoch_secs < one.epoch_secs / 4.0);
    }

    #[test]
    fn gpu_single_epoch_magnitude_sane() {
        // Paper reports 2.7 days for ~1000 epochs-ish single-GPU training
        // runs; one 4.5M epoch on 8 GPUs is ~60s. Accept the right order
        // of magnitude (this is a model, not a measurement).
        let gpu = GpuArch::a100();
        let e = estimate_gpu_epoch(&water(4_500_000, 60.0, 90), &SchNetDims::default(), 8, &gpu);
        assert!(
            (10.0..=600.0).contains(&e.epoch_secs),
            "epoch {}s",
            e.epoch_secs
        );
    }
}
