//! Tiny property-testing harness (no proptest crate offline): runs a
//! closure over `n` seeded random cases and reports the failing seed so a
//! failure reproduces with `case(seed)`.
//!
//! ```ignore
//! check(100, |rng| {
//!     let xs = gen_sizes(rng, 1, 90, 200);
//!     let packs = lpfhp(&xs, 96, None);
//!     assert_partition(&xs, &packs);
//! });
//! ```

use std::sync::{Mutex, PoisonError};

use crate::util::Rng;

/// Serializes panic-hook swaps across concurrently running `check`
/// calls: the hook is process-global, so an unguarded swap could strand
/// the silent hook after interleaved take/set pairs.
static HOOK_SCOPE: Mutex<()> = Mutex::new(());

/// Run `body` over `cases` random number generators derived from a fixed
/// master seed (deterministic across runs). Panics with the case seed on
/// the first failure.
///
/// The default panic hook is silenced for the duration (and restored
/// before reporting): each probed case runs under `catch_unwind`, and a
/// property that fails hundreds of cases — or deliberately drives
/// expected panics — would otherwise spew one backtrace per case into
/// the test output. Caveat: the hook is process-global, so a panic in
/// an *unrelated* concurrent test is silenced too for the window of the
/// run; `check` calls themselves are serialized by an internal lock.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, body: F) {
    let scope = HOOK_SCOPE.lock().unwrap_or_else(PoisonError::into_inner);
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut failure = None;
    for case in 0..cases {
        let seed = 0xC0FFEE ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            body(&mut rng);
        });
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            failure = Some((case, seed, msg));
            break;
        }
    }
    // restore the saved hook BEFORE reporting, so the seed-bearing
    // panic below prints through the normal machinery
    std::panic::set_hook(prev);
    drop(scope);
    if let Some((case, seed, msg)) = failure {
        panic!("property failed on case {case} (seed {seed:#x}): {msg}");
    }
}

/// Uniform random usize vector in [lo, hi], length in [1, max_len].
pub fn gen_sizes(rng: &mut Rng, lo: usize, hi: usize, max_len: usize) -> Vec<usize> {
    let len = rng.range(1, max_len + 1);
    (0..len).map(|_| rng.range(lo, hi + 1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check(50, |rng| {
            let x = rng.range(0, 100);
            assert!(x < 100);
        });
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn check_reports_failing_seed() {
        check(50, |rng| {
            let x = rng.range(0, 100);
            assert!(x < 95, "x was {x}");
        });
    }

    #[test]
    fn gen_sizes_respects_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = gen_sizes(&mut rng, 3, 30, 50);
            assert!(!v.is_empty() && v.len() <= 50);
            assert!(v.iter().all(|&s| (3..=30).contains(&s)));
        }
    }
}
