//! Small dependency-free utilities: seeded RNG, JSON, plotting, stats,
//! property testing, and a deterministic schedule explorer.

pub mod json;
pub mod ledger;
pub mod mmap;
pub mod plot;
pub mod proptest;
pub mod rng;
pub mod sched;
pub mod stats;

pub use rng::Rng;
