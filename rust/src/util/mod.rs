//! Small dependency-free utilities: seeded RNG, JSON, plotting, stats.

pub mod json;
pub mod plot;
pub mod proptest;
pub mod rng;
pub mod stats;

pub use rng::Rng;
