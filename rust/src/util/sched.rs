//! Loom-lite deterministic schedule explorer for concurrency protocols.
//!
//! Instead of real threads, a scenario models each thread as an *actor*:
//! a closure that, when scheduled, performs at most one atomic step
//! against the shared state and reports whether it [`Step::Ran`], is
//! [`Step::Blocked`] (would wait — e.g. on a full channel or an empty
//! pool), or is [`Step::Done`]. The explorer then drives the actors
//! through thousands of seeded pseudo-random interleavings, checking a
//! state invariant after every step and a finale predicate at
//! quiescence. Because the schedule is a pure function of the seed, any
//! violation replays exactly with [`Explorer::replay`].
//!
//! Contract: a `Blocked` return must be side-effect-free — the explorer
//! may probe a blocked actor any number of times while sweeping for a
//! runnable one, and uses "every live actor blocked" as its deadlock
//! detector.
//!
//! Used by `tests/race.rs` to drive the dispatcher/credit/lease
//! protocol; see `make race`.

use crate::util::Rng;

/// Outcome of scheduling one actor for one step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// The actor performed one atomic step against the state.
    Ran,
    /// The actor cannot progress right now (side-effect-free).
    Blocked,
    /// The actor has finished and must not be scheduled again.
    Done,
}

/// A modeled thread: one atomic step per invocation.
pub type Actor<S> = Box<dyn FnMut(&mut S) -> Step>;

/// One concurrency scenario: shared state, actors, a per-step invariant,
/// and a finale predicate checked when every actor is done.
pub struct Scenario<S> {
    state: S,
    actors: Vec<(String, Actor<S>)>,
    invariant: Box<dyn Fn(&S) -> Result<(), String>>,
    finale: Box<dyn Fn(&S) -> Result<(), String>>,
}

impl<S> Scenario<S> {
    /// A scenario over `state` with no actors and vacuous checks.
    pub fn new(state: S) -> Self {
        Scenario {
            state,
            actors: Vec::new(),
            invariant: Box::new(|_| Ok(())),
            finale: Box::new(|_| Ok(())),
        }
    }

    /// Add a modeled thread. `name` labels violations.
    #[must_use]
    pub fn with_actor(mut self, name: &str, f: impl FnMut(&mut S) -> Step + 'static) -> Self {
        self.actors.push((name.to_string(), Box::new(f)));
        self
    }

    /// Predicate checked after every step; `Err(msg)` is a violation.
    #[must_use]
    pub fn with_invariant(mut self, f: impl Fn(&S) -> Result<(), String> + 'static) -> Self {
        self.invariant = Box::new(f);
        self
    }

    /// Predicate checked once all actors are done.
    #[must_use]
    pub fn with_finale(mut self, f: impl Fn(&S) -> Result<(), String> + 'static) -> Self {
        self.finale = Box::new(f);
        self
    }
}

/// A failed schedule: everything needed to reproduce and diagnose it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Schedule seed; feed to [`Explorer::replay`] to reproduce.
    pub seed: u64,
    /// Steps executed when the violation fired.
    pub step: u64,
    /// Name of the actor whose step (or absence of steps) triggered it.
    pub actor: String,
    /// The invariant/finale/deadlock message.
    pub message: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "schedule seed {:#018x} failed at step {} (actor `{}`): {}\n\
             replay: MOLPACK_RACE_SEED={:#x} cargo test --test race -- --nocapture",
            self.seed, self.step, self.actor, self.message, self.seed
        )
    }
}

/// Counters for a clean exploration run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunStats {
    /// Schedules explored.
    pub schedules: u64,
    /// Total actor steps executed across all schedules.
    pub steps: u64,
}

/// Drives a scenario builder through many seeded interleavings.
pub struct Explorer {
    /// Number of schedules to explore.
    pub schedules: u64,
    /// Master seed; per-schedule seeds derive from it.
    pub master_seed: u64,
    /// Per-schedule step budget; exceeding it is reported as livelock.
    pub max_steps: u64,
}

impl Explorer {
    /// Explore `schedules` interleavings derived from `master_seed`.
    pub fn new(schedules: u64, master_seed: u64) -> Self {
        Explorer { schedules, master_seed, max_steps: 20_000 }
    }

    /// Like [`Explorer::new`], honouring `MOLPACK_RACE_SCHEDULES` as a
    /// schedule-count override (so CI can run a deeper pass than the
    /// default `cargo test`).
    pub fn from_env(default_schedules: u64, master_seed: u64) -> Self {
        let schedules = std::env::var("MOLPACK_RACE_SCHEDULES")
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(default_schedules);
        Explorer::new(schedules, master_seed)
    }

    /// Seed of the `i`-th schedule (splitmix-style stream from the
    /// master seed, matching the crate's proptest seeding idiom).
    pub fn schedule_seed(&self, i: u64) -> u64 {
        self.master_seed ^ (i + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
    }

    /// Run the full exploration. `build` constructs a fresh scenario per
    /// schedule (use the provided rng for randomized shapes). Returns
    /// the first violation, or stats for a clean run.
    #[must_use = "an unchecked exploration error drops a found schedule violation"]
    pub fn run<S>(
        &self,
        build: impl Fn(&mut Rng) -> Scenario<S>,
    ) -> Result<RunStats, Box<Violation>> {
        let mut steps = 0;
        for i in 0..self.schedules {
            let seed = self.schedule_seed(i);
            steps += self.run_one(seed, &build)?;
        }
        Ok(RunStats { schedules: self.schedules, steps })
    }

    /// Re-run exactly one schedule by its seed (from a violation
    /// report, or `MOLPACK_RACE_SEED`).
    #[must_use = "an unchecked replay error drops the violation it should reproduce"]
    pub fn replay<S>(
        &self,
        seed: u64,
        build: impl Fn(&mut Rng) -> Scenario<S>,
    ) -> Result<u64, Box<Violation>> {
        self.run_one(seed, &build)
    }

    fn run_one<S>(
        &self,
        seed: u64,
        build: &impl Fn(&mut Rng) -> Scenario<S>,
    ) -> Result<u64, Box<Violation>> {
        let mut rng = Rng::new(seed);
        let mut sc = build(&mut rng);
        let Scenario { ref mut state, ref mut actors, ref invariant, ref finale } = sc;
        let mut done = vec![false; actors.len()];
        let mut steps: u64 = 0;
        loop {
            let enabled: Vec<usize> =
                (0..actors.len()).filter(|&i| !done[i]).collect();
            if enabled.is_empty() {
                break;
            }
            if steps >= self.max_steps {
                return Err(Box::new(Violation {
                    seed,
                    step: steps,
                    actor: "<scheduler>".to_string(),
                    message: format!("livelock: exceeded {} steps", self.max_steps),
                }));
            }
            // pick a random enabled actor; sweep forward until one runs
            let start = rng.range(0, enabled.len());
            let mut progressed = false;
            for k in 0..enabled.len() {
                let ai = enabled[(start + k) % enabled.len()];
                match (actors[ai].1)(state) {
                    Step::Blocked => continue,
                    r => {
                        if r == Step::Done {
                            done[ai] = true;
                        }
                        steps += 1;
                        progressed = true;
                        if let Err(message) = invariant(state) {
                            return Err(Box::new(Violation {
                                seed,
                                step: steps,
                                actor: actors[ai].0.clone(),
                                message,
                            }));
                        }
                        break;
                    }
                }
            }
            if !progressed {
                return Err(Box::new(Violation {
                    seed,
                    step: steps,
                    actor: "<scheduler>".to_string(),
                    message: format!(
                        "deadlock: {} actors alive, all blocked",
                        enabled.len()
                    ),
                }));
            }
        }
        if let Err(message) = finale(state) {
            return Err(Box::new(Violation {
                seed,
                step: steps,
                actor: "<finale>".to_string(),
                message,
            }));
        }
        Ok(steps)
    }
}

/// Parse a seed string as decimal or `0x…` hex (the format printed in
/// violation reports), for the `MOLPACK_RACE_SEED` replay hook.
pub fn parse_seed(s: &str) -> Option<u64> {
    let t = s.trim();
    if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16).ok()
    } else {
        t.parse().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A 2-actor ping-pong: producer sends 0..n through a 1-slot cell,
    // consumer sums. Any interleaving must deliver every value.
    fn ping_pong(n: u32) -> Scenario<(Option<u32>, u32, u32)> {
        // state: (cell, next_to_send, sum)
        let mut received = 0u32;
        Scenario::new((None, 0u32, 0u32))
            .with_actor("producer", move |st: &mut (Option<u32>, u32, u32)| {
                if st.1 >= n {
                    return Step::Done;
                }
                if st.0.is_some() {
                    return Step::Blocked;
                }
                st.0 = Some(st.1);
                st.1 += 1;
                Step::Ran
            })
            .with_actor("consumer", move |st: &mut (Option<u32>, u32, u32)| {
                match st.0.take() {
                    Some(v) => {
                        st.2 += v;
                        received += 1;
                        if received == n {
                            Step::Done
                        } else {
                            Step::Ran
                        }
                    }
                    None => Step::Blocked,
                }
            })
            .with_finale(move |st| {
                let want = n * n.saturating_sub(1) / 2;
                if st.2 == want {
                    Ok(())
                } else {
                    Err(format!("sum {} != {want}", st.2))
                }
            })
    }

    #[test]
    fn ping_pong_passes_many_schedules() {
        let stats = Explorer::new(200, 0xBEEF)
            .run(|rng| ping_pong(rng.range(1, 9) as u32))
            .expect("ping-pong is race-free");
        assert_eq!(stats.schedules, 200);
        assert!(stats.steps > 0);
    }

    #[test]
    fn deadlock_is_detected() {
        // two actors each blocked forever waiting on the other
        let v = Explorer::new(1, 7)
            .run(|_| {
                Scenario::new(())
                    .with_actor("a", |_: &mut ()| Step::Blocked)
                    .with_actor("b", |_: &mut ()| Step::Blocked)
            })
            .expect_err("must deadlock");
        assert!(v.message.contains("deadlock"), "{v}");
        assert_eq!(v.step, 0);
    }

    #[test]
    fn livelock_hits_the_step_budget() {
        let mut ex = Explorer::new(1, 7);
        ex.max_steps = 50;
        let v = ex
            .run(|_| Scenario::new(()).with_actor("spin", |_: &mut ()| Step::Ran))
            .expect_err("must livelock");
        assert!(v.message.contains("livelock"), "{v}");
        assert_eq!(v.step, 50);
    }

    #[test]
    fn violations_replay_identically() {
        let build = |rng: &mut Rng| {
            let trip = rng.range(2, 20) as u32;
            Scenario::new(0u32)
                .with_actor("inc", move |st: &mut u32| {
                    *st += 1;
                    if *st > 100 {
                        Step::Done
                    } else {
                        Step::Ran
                    }
                })
                .with_invariant(move |st| {
                    if *st == trip {
                        Err(format!("tripped at {st}"))
                    } else {
                        Ok(())
                    }
                })
        };
        let ex = Explorer::new(50, 0xD00D);
        let v = ex.run(build).expect_err("always trips");
        let v2 = ex.replay(v.seed, build).expect_err("replay trips too");
        assert_eq!(v, v2, "replay must reproduce the identical violation");
        assert!(v.to_string().contains("MOLPACK_RACE_SEED"));
    }

    #[test]
    fn from_env_defaults_without_override() {
        // avoid set_var (process-global, racy under parallel tests):
        // branch on whether the variable is already present.
        let ex = Explorer::from_env(123, 1);
        match std::env::var("MOLPACK_RACE_SCHEDULES") {
            Err(_) => assert_eq!(ex.schedules, 123),
            Ok(v) => assert_eq!(ex.schedules, v.trim().parse::<u64>().unwrap_or(123)),
        }
    }

    #[test]
    fn parse_seed_accepts_hex_and_decimal() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed("0x2a"), Some(42));
        assert_eq!(parse_seed(" 0X2A "), Some(42));
        assert_eq!(parse_seed("nope"), None);
    }
}
