//! Read-only `mmap(2)` without libc: a raw-syscall shim over
//! `std::os::fd`, so the persist cache file can be served as
//! page-cache-backed memory (one physical copy shared by every plane in
//! every process on the host) with zero crate dependencies.
//!
//! Supported targets are Linux on x86_64/aarch64 — the shim issues the
//! `mmap`/`munmap`/`madvise` syscalls directly via inline asm. On any
//! other target [`Mmap::map`] returns `ErrorKind::Unsupported` and
//! callers (see `datasets::persist`) fall back to an owned bulk read,
//! so the build stays portable without a feature flag.
//!
//! Mappings are `PROT_READ` + `MAP_SHARED`: readers can never mutate the
//! cache through the map, and all processes mapping the same file share
//! physical pages. The SIGBUS caveat of shared file mappings (touching a
//! page past a truncated file's end) is handled by protocol, not by
//! signal handling: cache writers only ever *replace* the file via
//! temp-file + `rename` or *grow* it by appending — an existing file is
//! never truncated in place — so a live mapping's pages stay valid for
//! the mapping's lifetime.

use std::fs::File;
use std::io;
use std::ops::Deref;

/// Whether this build target has the raw-syscall mapping path at all.
/// When false, [`Mmap::map`] always returns `ErrorKind::Unsupported`.
pub const SUPPORTED: bool = cfg!(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
));

#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod sys {
    //! The actual syscall shim. Numbers differ per architecture; flag
    //! and protection constants below are identical on both.

    pub const PROT_READ: usize = 1;
    pub const MAP_SHARED: usize = 1;
    pub const MADV_WILLNEED: usize = 3;

    #[cfg(target_arch = "x86_64")]
    pub const SYS_MMAP: usize = 9;
    #[cfg(target_arch = "x86_64")]
    pub const SYS_MUNMAP: usize = 11;
    #[cfg(target_arch = "x86_64")]
    pub const SYS_MADVISE: usize = 28;

    #[cfg(target_arch = "aarch64")]
    pub const SYS_MMAP: usize = 222;
    #[cfg(target_arch = "aarch64")]
    pub const SYS_MUNMAP: usize = 215;
    #[cfg(target_arch = "aarch64")]
    pub const SYS_MADVISE: usize = 233;

    /// Raw six-argument syscall. Returns the kernel's raw result:
    /// `-4095..=-1` encodes `-errno`, anything else is success.
    ///
    /// # Safety
    /// The caller must uphold the invariants of the specific syscall
    /// being issued (valid addresses, lengths, fds).
    #[cfg(target_arch = "x86_64")]
    pub unsafe fn syscall6(
        n: usize,
        a: usize,
        b: usize,
        c: usize,
        d: usize,
        e: usize,
        f: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a,
            in("rsi") b,
            in("rdx") c,
            in("r10") d,
            in("r8") e,
            in("r9") f,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    /// Raw six-argument syscall (aarch64 calling convention).
    ///
    /// # Safety
    /// As for the x86_64 variant.
    #[cfg(target_arch = "aarch64")]
    pub unsafe fn syscall6(
        n: usize,
        a: usize,
        b: usize,
        c: usize,
        d: usize,
        e: usize,
        f: usize,
    ) -> isize {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a => ret,
            in("x1") b,
            in("x2") c,
            in("x3") d,
            in("x4") e,
            in("x5") f,
            options(nostack),
        );
        ret
    }

    /// Convert a raw syscall result to `io::Result<usize>`.
    pub(crate) fn decode(ret: isize) -> std::io::Result<usize> {
        if (-4095..0).contains(&(ret as i64)) {
            Err(std::io::Error::from_raw_os_error(-(ret as i32)))
        } else {
            Ok(ret as usize)
        }
    }
}

/// A read-only, shared memory mapping of an entire file.
///
/// Dereferences to `&[u8]` over the file's bytes at map time. The
/// mapping is unmapped on drop. `Send + Sync`: the pages are immutable
/// through this mapping and the kernel keeps them alive until `munmap`.
#[derive(Debug)]
pub struct Mmap {
    /// Page-aligned base address; null iff `len == 0` (zero-length
    /// mappings are invalid at the syscall level, so empty files are
    /// represented without one).
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ — no writes can occur through it —
// and its lifetime is tied to this struct, so sharing references across
// threads is sound.
unsafe impl Send for Mmap {}
// SAFETY: as above; &Mmap only permits reads of immutable pages.
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map the whole of `file` read-only and shared.
    ///
    /// Fails with `ErrorKind::Unsupported` on targets without the
    /// syscall shim (see [`SUPPORTED`]); callers should treat that the
    /// same as any other map failure and fall back to a bulk read.
    #[must_use = "the mapping is the only handle to the mapped bytes"]
    pub fn map(file: &File) -> io::Result<Mmap> {
        #[cfg(all(
            target_os = "linux",
            any(target_arch = "x86_64", target_arch = "aarch64")
        ))]
        {
            use std::os::fd::AsRawFd;
            let len = file.metadata()?.len();
            if len > usize::MAX as u64 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    "file too large to map on this platform",
                ));
            }
            let len = len as usize;
            if len == 0 {
                return Ok(Mmap {
                    ptr: std::ptr::null(),
                    len: 0,
                });
            }
            // SAFETY: addr=0 lets the kernel pick a placement; fd/len
            // come from the live `File`; PROT_READ + MAP_SHARED request
            // a read-only view, so no aliasing writes are possible
            // through the returned pages.
            let ret = unsafe {
                sys::syscall6(
                    sys::SYS_MMAP,
                    0,
                    len,
                    sys::PROT_READ,
                    sys::MAP_SHARED,
                    file.as_raw_fd() as usize,
                    0,
                )
            };
            let addr = sys::decode(ret)?;
            Ok(Mmap {
                ptr: addr as *const u8,
                len,
            })
        }
        #[cfg(not(all(
            target_os = "linux",
            any(target_arch = "x86_64", target_arch = "aarch64")
        )))]
        {
            let _ = file;
            Err(io::Error::new(
                io::ErrorKind::Unsupported,
                "mmap is not supported on this target; use the owned bulk-read path",
            ))
        }
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the mapped file was empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Best-effort `madvise(MADV_WILLNEED)` over the whole mapping:
    /// asks the kernel to start faulting pages in ahead of first touch.
    /// Errors are ignored — this is purely a prefetch hint.
    pub fn advise_willneed(&self) {
        #[cfg(all(
            target_os = "linux",
            any(target_arch = "x86_64", target_arch = "aarch64")
        ))]
        if self.len > 0 {
            // SAFETY: ptr/len describe exactly the live mapping owned by
            // self; MADV_WILLNEED does not change the mapping.
            let ret = unsafe {
                sys::syscall6(
                    sys::SYS_MADVISE,
                    self.ptr as usize,
                    self.len,
                    sys::MADV_WILLNEED,
                    0,
                    0,
                    0,
                )
            };
            let _ = sys::decode(ret);
        }
    }
}

impl Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        if self.len == 0 {
            &[]
        } else {
            // SAFETY: ptr is the base of a live mapping of exactly `len`
            // readable bytes (established in `map`, torn down only in
            // `drop`), and the writer protocol (module docs) guarantees
            // the backing file is never truncated under the mapping.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(
            target_os = "linux",
            any(target_arch = "x86_64", target_arch = "aarch64")
        ))]
        if self.len > 0 {
            // SAFETY: ptr/len are exactly what mmap returned; after this
            // call nothing dereferences them (self is being dropped).
            let ret = unsafe {
                sys::syscall6(sys::SYS_MUNMAP, self.ptr as usize, self.len, 0, 0, 0, 0)
            };
            debug_assert!(sys::decode(ret).is_ok(), "munmap of a live mapping failed");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmppath(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("molpack-mmap-{}-{}", std::process::id(), name));
        p
    }

    #[test]
    fn maps_file_contents_exactly() {
        if !SUPPORTED {
            return;
        }
        let path = tmppath("basic");
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i * 2654435761) as u8).collect();
        std::fs::write(&path, &payload).unwrap();
        let f = File::open(&path).unwrap();
        let m = Mmap::map(&f).unwrap();
        m.advise_willneed();
        assert_eq!(m.len(), payload.len());
        assert_eq!(&m[..], &payload[..]);
        drop(m);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        if !SUPPORTED {
            return;
        }
        let path = tmppath("empty");
        std::fs::write(&path, b"").unwrap();
        let f = File::open(&path).unwrap();
        let m = Mmap::map(&f).unwrap();
        assert!(m.is_empty());
        assert_eq!(&m[..], b"");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn two_mappings_of_one_file_agree() {
        if !SUPPORTED {
            return;
        }
        let path = tmppath("twice");
        let mut f = File::create(&path).unwrap();
        f.write_all(&[7u8; 4096 * 3 + 17]).unwrap();
        f.sync_all().unwrap();
        drop(f);
        let f = File::open(&path).unwrap();
        let a = Mmap::map(&f).unwrap();
        let b = Mmap::map(&f).unwrap();
        assert_eq!(&a[..], &b[..]);
        // Exercise Send/Sync: read the first map from another thread
        // while this one holds the second.
        let a = std::sync::Arc::new(a);
        let a2 = std::sync::Arc::clone(&a);
        let sum: u64 = std::thread::spawn(move || a2.iter().map(|&x| x as u64).sum())
            .join()
            .unwrap();
        assert_eq!(sum, 7 * (4096 * 3 + 17));
        std::fs::remove_file(&path).unwrap();
    }
}
