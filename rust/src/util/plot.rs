//! Terminal plotting for the figure harness: line series, bar charts and
//! histograms rendered as Unicode text. The paper's figures are regenerated
//! as data rows (for EXPERIMENTS.md) plus these quick-look plots.

/// Render one or more named series as an ASCII line chart.
pub fn line_chart(
    title: &str,
    x: &[f64],
    series: &[(&str, Vec<f64>)],
    width: usize,
    height: usize,
) -> String {
    assert!(!x.is_empty() && !series.is_empty());
    let marks = ['o', 'x', '+', '*', '#', '@'];
    let ymin = series
        .iter()
        .flat_map(|(_, ys)| ys.iter())
        .cloned()
        .fold(f64::INFINITY, f64::min);
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter())
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    let yspan = if (ymax - ymin).abs() < 1e-12 { 1.0 } else { ymax - ymin };
    let xmin = x.iter().cloned().fold(f64::INFINITY, f64::min);
    let xmax = x.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let xspan = if (xmax - xmin).abs() < 1e-12 { 1.0 } else { xmax - xmin };

    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (&xi, &yi) in x.iter().zip(ys.iter()) {
            let col = (((xi - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = (((yi - ymin) / yspan) * (height - 1) as f64).round() as usize;
            let row = height - 1 - row.min(height - 1);
            grid[row][col.min(width - 1)] = marks[si % marks.len()];
        }
    }

    let mut out = format!("{title}\n");
    for (i, row) in grid.iter().enumerate() {
        let yv = ymax - (i as f64 / (height - 1) as f64) * yspan;
        out.push_str(&format!("{yv:>10.3} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}  {:<w$.3}{:>.3}\n",
        "",
        xmin,
        xmax,
        w = width.saturating_sub(6)
    ));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], name));
    }
    out
}

/// Horizontal bar chart with labels.
pub fn bar_chart(title: &str, rows: &[(String, f64)], width: usize) -> String {
    let max = rows.iter().map(|(_, v)| *v).fold(f64::NEG_INFINITY, f64::max);
    let lw = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in rows {
        let n = if max > 0.0 { ((v / max) * width as f64).round() as usize } else { 0 };
        out.push_str(&format!("  {label:>lw$} | {} {v:.3}\n", "#".repeat(n)));
    }
    out
}

/// Markdown table: header + aligned rows — the canonical EXPERIMENTS.md form.
pub fn md_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        out.push('|');
        for (c, w) in row.iter().zip(&widths) {
            out.push_str(&format!(" {c:<w$} |"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_contains_marks_and_legend() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let s = vec![("up", vec![1.0, 2.0, 3.0, 4.0]), ("down", vec![4.0, 3.0, 2.0, 1.0])];
        let out = line_chart("t", &x, &s, 40, 10);
        assert!(out.contains('o') && out.contains('x'));
        assert!(out.contains("up") && out.contains("down"));
    }

    #[test]
    fn line_chart_handles_flat_series() {
        let out = line_chart("flat", &[0.0, 1.0], &[("c", vec![5.0, 5.0])], 20, 5);
        assert!(out.contains('o'));
    }

    #[test]
    fn bar_chart_scales_to_width() {
        let rows = vec![("a".to_string(), 1.0), ("bb".to_string(), 2.0)];
        let out = bar_chart("bars", &rows, 10);
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[2].matches('#').count() == 10);
        assert!(lines[1].matches('#').count() == 5);
    }

    #[test]
    fn md_table_is_well_formed() {
        let t = md_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.starts_with('|') && l.ends_with('|')));
    }
}
