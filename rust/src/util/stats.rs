//! Descriptive statistics + timing helpers used by the bench harness and
//! the dataset characterization (paper Fig. 5).

use std::time::{Duration, Instant};

/// Summary statistics over a sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize over empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: s[0],
        p50: percentile_sorted(&s, 50.0),
        p95: percentile_sorted(&s, 95.0),
        max: s[n - 1],
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Fixed-width histogram over [lo, hi].
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins] }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = ((x - self.lo) / (self.hi - self.lo) * bins as f64).floor();
        let idx = (t.max(0.0) as usize).min(bins - 1);
        self.counts[idx] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }
}

/// Gaussian kernel density estimate evaluated on a grid — mirrors the KDE
/// panels of paper Fig. 5.
pub fn kde(samples: &[f64], grid: &[f64], bandwidth: f64) -> Vec<f64> {
    assert!(bandwidth > 0.0 && !samples.is_empty());
    let norm = 1.0 / (samples.len() as f64 * bandwidth * (2.0 * std::f64::consts::PI).sqrt());
    grid.iter()
        .map(|&g| {
            samples
                .iter()
                .map(|&x| {
                    let u = (g - x) / bandwidth;
                    (-0.5 * u * u).exp()
                })
                .sum::<f64>()
                * norm
        })
        .collect()
}

/// Measure a closure repeatedly: `warmup` unrecorded runs, then `iters`
/// timed runs. Returns per-iteration times in seconds. This is the core of
/// the criterion-free bench harness.
pub fn time_it<F: FnMut()>(mut f: F, warmup: usize, iters: usize) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

/// Simple stopwatch for phase profiling.
pub struct Stopwatch {
    start: Instant,
    pub laps: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now(), laps: Vec::new() }
    }

    pub fn lap(&mut self, label: &str) {
        let now = Instant::now();
        self.laps.push((label.to_string(), now - self.start));
        self.start = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant_sample() {
        let s = summarize(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!((s.min, s.max), (2.0, 2.0));
    }

    #[test]
    fn percentiles_interpolate() {
        let s: Vec<f64> = (0..=100).map(|x| x as f64).collect();
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 50.0), 50.0);
        assert_eq!(percentile_sorted(&s, 100.0), 100.0);
        assert!((percentile_sorted(&[1.0, 2.0], 50.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add(0.5);
        h.add(9.99);
        h.add(-5.0); // clamped to first bin
        h.add(50.0); // clamped to last bin
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 2);
        assert_eq!(h.total(), 4);
        assert!((h.bin_center(0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn kde_integrates_to_one() {
        let samples = [1.0, 2.0, 3.0];
        let grid: Vec<f64> = (-200..600).map(|i| i as f64 * 0.01).collect();
        let dens = kde(&samples, &grid, 0.5);
        let integral: f64 = dens.iter().sum::<f64>() * 0.01;
        assert!((integral - 1.0).abs() < 0.01, "integral {integral}");
    }

    #[test]
    fn kde_peaks_near_samples() {
        let samples = [5.0];
        let grid = [4.0, 5.0, 6.0];
        let dens = kde(&samples, &grid, 0.3);
        assert!(dens[1] > dens[0] && dens[1] > dens[2]);
    }

    #[test]
    fn time_it_returns_requested_iters() {
        let t = time_it(
            || {
                std::hint::black_box(1 + 1);
            },
            2,
            5,
        );
        assert_eq!(t.len(), 5);
        assert!(t.iter().all(|&x| x >= 0.0));
    }
}
