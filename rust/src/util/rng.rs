//! Deterministic xoshiro256** RNG — no external deps, reproducible across
//! platforms. Used by the synthetic dataset generators, the property-test
//! harness, and the workload generators in the benches.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive sum");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-worker RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_inclusive_exclusive() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range(3, 7);
            assert!((3..7).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 6;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy_bins() {
        let mut r = Rng::new(13);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
