//! Minimal JSON parser + writer (no serde available offline).
//!
//! Supports the full JSON grammar we emit/consume: objects, arrays,
//! strings with escapes, numbers, bools, null. Insertion order of object
//! keys is preserved (Vec of pairs) so written files diff cleanly.

use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, thiserror::Error)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character {0:?} at byte {1}")]
    Unexpected(char, usize),
    #[error("invalid number at byte {0}")]
    BadNumber(usize),
    #[error("invalid escape at byte {0}")]
    BadEscape(usize),
    #[error("trailing data at byte {0}")]
    Trailing(usize),
    #[error("missing key {0:?}")]
    MissingKey(String),
    #[error("type mismatch: wanted {0}")]
    Type(&'static str),
}

impl Json {
    #[must_use = "an unchecked parse error hides malformed JSON"]
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != b.len() {
            return Err(JsonError::Trailing(p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    #[must_use = "the Err reports a missing key the caller assumed present"]
    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        match self {
            Json::Obj(pairs) => pairs
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| JsonError::MissingKey(key.to_string())),
            _ => Err(JsonError::Type("object")),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    #[must_use = "the Err reports a type mismatch; ignoring it serves garbage"]
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(JsonError::Type("number")),
        }
    }

    #[must_use = "the Err reports a type mismatch; ignoring it serves garbage"]
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(JsonError::Type("non-negative integer"));
        }
        Ok(x as usize)
    }

    #[must_use = "the Err reports a type mismatch; ignoring it serves garbage"]
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }

    #[must_use = "the Err reports a type mismatch; ignoring it serves garbage"]
    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(JsonError::Type("array")),
        }
    }

    #[must_use = "the Err reports a type mismatch; ignoring it serves garbage"]
    pub fn as_obj(&self) -> Result<&[(String, Json)], JsonError> {
        match self {
            Json::Obj(v) => Ok(v),
            _ => Err(JsonError::Type("object")),
        }
    }

    #[must_use = "the Err reports a type mismatch; ignoring it serves garbage"]
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]`.
    #[must_use = "the Err reports a type mismatch; ignoring it serves garbage"]
    pub fn as_usize_arr(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8, JsonError> {
        self.b.get(self.i).copied().ok_or(JsonError::Eof(self.i))
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        let got = self.peek()?;
        if got != c {
            return Err(JsonError::Unexpected(got as char, self.i));
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(JsonError::Unexpected(self.peek()? as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(JsonError::Unexpected(c as char, self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => return Err(JsonError::Unexpected(c as char, self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(JsonError::BadEscape(self.i));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::BadEscape(self.i))?;
                            self.i += 4;
                            // BMP only; surrogate pairs are not emitted by
                            // our writers, map them to replacement char.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(JsonError::BadEscape(self.i)),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| JsonError::BadEscape(start))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(JsonError::BadNumber(start))
    }
}

// ---- writer ----------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(pairs) => {
                write!(f, "{{")?;
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Convenience builder for writing result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
        assert_eq!(*v.get("c").unwrap(), Json::Null);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn roundtrips_through_display() {
        let src = r#"{"name":"m\"x","vals":[1,2.5,-3],"ok":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn usize_arr_and_errors() {
        let v = Json::parse("[3, 4, 5]").unwrap();
        assert_eq!(v.as_usize_arr().unwrap(), vec![3, 4, 5]);
        assert!(Json::parse("[3, -1]").unwrap().as_usize_arr().is_err());
        assert!(Json::parse("[3.5]").unwrap().as_usize_arr().is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.clone()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // Integration-ish: parse the actual artifact manifest when built.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("param_count").unwrap().as_usize().unwrap() > 0);
        }
    }
}
