//! Perf ledger: compare a fresh bench snapshot against the committed
//! baseline in `BENCH_history/` and flag regressions beyond a tolerance
//! band.
//!
//! Bench sections emit flat JSON objects of metrics
//! (`BENCH_assembly.json`, `BENCH_persist.json`); `make bench-record`
//! copies them into `BENCH_history/` together with gate wall times, and
//! `make bench-check` replays the benches and runs `molpack benchdiff`
//! against that baseline. Which way "better" points is inferred from the
//! metric name, so new bench fields join the guard without schema
//! changes:
//!
//! * `*_secs` / `*_ms` / `*_bytes` — lower is better (latency, wall
//!   time, footprint);
//! * `*per_sec*` / `*speedup` / `*hit_rate` — higher is better
//!   (throughput, ratios);
//! * anything else (counts, labels, flags) — informational, never
//!   compared.
//!
//! Nested objects are flattened to dotted paths (`gates.lint_secs`), so
//! one baseline file can hold several sections. A directional metric
//! present in the baseline but missing from the current run is reported
//! (and fails the check): silently dropping a guarded metric is itself a
//! regression of the guard.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Which way "better" points for one metric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Timings, footprints: regression = current above baseline.
    LowerIsBetter,
    /// Throughput, speedups, hit rates: regression = current below.
    HigherIsBetter,
}

/// Infer the comparison direction from a metric name (see module docs);
/// `None` marks an informational metric that is never compared.
pub fn direction(name: &str) -> Option<Direction> {
    let last = name.rsplit('.').next().unwrap_or(name);
    if last.ends_with("_secs") || last.ends_with("_ms") || last.ends_with("_bytes") {
        Some(Direction::LowerIsBetter)
    } else if last.contains("per_sec") || last.ends_with("speedup") || last.ends_with("hit_rate")
    {
        Some(Direction::HigherIsBetter)
    } else {
        None
    }
}

/// One compared metric: baseline vs current, and the verdict under the
/// tolerance the comparison ran with.
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    /// Dotted metric path (e.g. `persist.warm_epoch1_secs`).
    pub metric: String,
    /// Value recorded in the committed baseline.
    pub baseline: f64,
    /// Value from the fresh run.
    pub current: f64,
    /// Which way "better" points for this metric.
    pub direction: Direction,
    /// True when `current` is worse than `baseline` beyond tolerance.
    pub regressed: bool,
}

impl Delta {
    /// Signed relative change in percent, positive = worse. Returns 0
    /// for a zero baseline (no meaningful ratio).
    pub fn worse_pct(&self) -> f64 {
        if self.baseline == 0.0 {
            return 0.0;
        }
        let rel = (self.current - self.baseline) / self.baseline * 100.0;
        match self.direction {
            Direction::LowerIsBetter => rel,
            Direction::HigherIsBetter => -rel,
        }
    }
}

/// Outcome of one baseline/current comparison.
#[derive(Debug, Default)]
pub struct Report {
    /// Every directional metric found in both files.
    pub deltas: Vec<Delta>,
    /// Directional baseline metrics absent from the current run.
    pub missing: Vec<String>,
}

impl Report {
    /// The failing subset of [`deltas`](Report::deltas).
    pub fn regressions(&self) -> Vec<&Delta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// Overall verdict: no regressions and no vanished metrics.
    pub fn is_pass(&self) -> bool {
        self.missing.is_empty() && self.deltas.iter().all(|d| !d.regressed)
    }
}

/// Flatten nested objects into `(dotted.path, value)` pairs, keeping
/// only numeric leaves.
fn collect(prefix: &str, v: &Json, out: &mut Vec<(String, f64)>) {
    match v {
        Json::Num(x) => out.push((prefix.to_string(), *x)),
        Json::Obj(pairs) => {
            for (k, child) in pairs {
                let path =
                    if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                collect(&path, child, out);
            }
        }
        _ => {}
    }
}

/// Compare two parsed snapshots under a relative `tolerance` (0.25 =
/// current may be up to 25% worse than baseline before failing).
/// Metrics only present in the current run are ignored — a new bench
/// field becomes guarded once `make bench-record` folds it into the
/// baseline.
#[must_use = "an unchecked comparison error hides an unreadable snapshot"]
pub fn compare(baseline: &Json, current: &Json, tolerance: f64) -> Result<Report> {
    if !(0.0..10.0).contains(&tolerance) {
        bail!("tolerance {tolerance} out of range [0, 10)");
    }
    let mut base = Vec::new();
    collect("", baseline, &mut base);
    let mut cur = Vec::new();
    collect("", current, &mut cur);
    let mut report = Report::default();
    for (name, b) in base {
        let Some(dir) = direction(&name) else { continue };
        let Some(&(_, c)) = cur.iter().find(|(n, _)| *n == name) else {
            report.missing.push(name);
            continue;
        };
        let regressed = match dir {
            Direction::LowerIsBetter => c > b * (1.0 + tolerance) + 1e-12,
            Direction::HigherIsBetter => c < b * (1.0 - tolerance) - 1e-12,
        };
        report.deltas.push(Delta {
            metric: name,
            baseline: b,
            current: c,
            direction: dir,
            regressed,
        });
    }
    Ok(report)
}

/// [`compare`] over files on disk (the `molpack benchdiff` entry point).
#[must_use = "an unchecked comparison error hides an unreadable snapshot"]
pub fn compare_files(
    baseline: &std::path::Path,
    current: &std::path::Path,
    tolerance: f64,
) -> Result<Report> {
    let read = |p: &std::path::Path| -> Result<Json> {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading snapshot {p:?}"))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing snapshot {p:?}: {e}"))
    };
    compare(&read(baseline)?, &read(current)?, tolerance)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn direction_inference_covers_the_bench_schema() {
        assert_eq!(direction("cold_epoch1_secs"), Some(Direction::LowerIsBetter));
        assert_eq!(direction("queue_wait_ms"), Some(Direction::LowerIsBetter));
        assert_eq!(direction("cache_file_bytes"), Some(Direction::LowerIsBetter));
        assert_eq!(direction("warm_graphs_per_sec"), Some(Direction::HigherIsBetter));
        assert_eq!(direction("speedup"), Some(Direction::HigherIsBetter));
        assert_eq!(direction("edge_hit_rate"), Some(Direction::HigherIsBetter));
        assert_eq!(direction("gates.lint_secs"), Some(Direction::LowerIsBetter));
        assert_eq!(direction("graphs"), None);
        assert_eq!(direction("bench"), None);
        assert_eq!(direction("bitwise_identical"), None);
    }

    #[test]
    fn within_tolerance_passes_and_beyond_fails_both_directions() {
        let base = parse(r#"{"warm_secs": 1.0, "speedup": 2.0, "graphs": 100}"#);
        let ok = parse(r#"{"warm_secs": 1.2, "speedup": 1.7, "graphs": 50}"#);
        let r = compare(&base, &ok, 0.25).unwrap();
        assert!(r.is_pass(), "{r:?}");
        assert_eq!(r.deltas.len(), 2, "informational keys must not be compared");

        let slow = parse(r#"{"warm_secs": 1.3, "speedup": 2.0}"#);
        let r = compare(&base, &slow, 0.25).unwrap();
        assert!(!r.is_pass());
        assert_eq!(r.regressions().len(), 1);
        assert_eq!(r.regressions()[0].metric, "warm_secs");
        assert!(r.regressions()[0].worse_pct() > 29.0);

        let weak = parse(r#"{"warm_secs": 1.0, "speedup": 1.4}"#);
        let r = compare(&base, &weak, 0.25).unwrap();
        assert_eq!(r.regressions().len(), 1);
        assert_eq!(r.regressions()[0].metric, "speedup");
    }

    #[test]
    fn improvements_never_fail() {
        let base = parse(r#"{"warm_secs": 1.0, "speedup": 2.0}"#);
        let better = parse(r#"{"warm_secs": 0.01, "speedup": 50.0}"#);
        assert!(compare(&base, &better, 0.0).unwrap().is_pass());
    }

    #[test]
    fn vanished_guarded_metric_fails_the_check() {
        let base = parse(r#"{"warm_secs": 1.0, "speedup": 2.0}"#);
        let cur = parse(r#"{"warm_secs": 1.0}"#);
        let r = compare(&base, &cur, 0.25).unwrap();
        assert!(!r.is_pass());
        assert_eq!(r.missing, vec!["speedup".to_string()]);
        // but a vanished *informational* key is fine
        let base = parse(r#"{"warm_secs": 1.0, "graphs": 9}"#);
        assert!(compare(&base, &cur, 0.25).unwrap().is_pass());
    }

    #[test]
    fn nested_sections_flatten_to_dotted_paths() {
        let base = parse(r#"{"gates": {"lint_secs": 10.0, "race_secs": 60.0}}"#);
        let cur = parse(r#"{"gates": {"lint_secs": 30.0, "race_secs": 55.0}}"#);
        let r = compare(&base, &cur, 0.5).unwrap();
        assert_eq!(r.deltas.len(), 2);
        let bad = r.regressions();
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].metric, "gates.lint_secs");
    }

    #[test]
    fn zero_baseline_is_stable() {
        let base = parse(r#"{"warm_secs": 0.0}"#);
        // any positive time regresses from a zero baseline ...
        let r = compare(&base, &parse(r#"{"warm_secs": 0.5}"#), 0.25).unwrap();
        assert!(!r.is_pass());
        assert_eq!(r.deltas[0].worse_pct(), 0.0, "no ratio from a zero baseline");
        // ... while exactly zero passes
        assert!(compare(&base, &base, 0.25).unwrap().is_pass());
    }

    #[test]
    fn bad_tolerance_is_rejected() {
        let j = parse("{}");
        assert!(compare(&j, &j, -0.1).is_err());
        assert!(compare(&j, &j, 10.0).is_err());
    }

    #[test]
    fn compare_files_round_trips() {
        let dir = std::env::temp_dir().join("molpack-ledger-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let pid = std::process::id();
        let b = dir.join(format!("base-{pid}.json"));
        let c = dir.join(format!("cur-{pid}.json"));
        std::fs::write(&b, r#"{"speedup": 2.0}"#).unwrap();
        std::fs::write(&c, r#"{"speedup": 2.1}"#).unwrap();
        assert!(compare_files(&b, &c, 0.25).unwrap().is_pass());
        assert!(compare_files(&b, &dir.join("absent.json"), 0.25).is_err());
        std::fs::write(&c, "not json").unwrap();
        assert!(compare_files(&b, &c, 0.25).is_err());
        std::fs::remove_file(b).ok();
        std::fs::remove_file(c).ok();
    }
}
