//! molpack — hardware/software co-design for molecular GNN training.
//!
//! Rust reproduction of "Extreme Acceleration of Graph Neural Network-based
//! Prediction Models for Quantum Chemistry" (Graphcore/PNNL, 2022).
//!
//! Three-layer architecture:
//! * **L3 (this crate)** — coordinator: datasets, batch packing (LPFHP,
//!   sharded for incremental planning), scatter/gather planner, BSP
//!   tile-machine performance model, a persistent **multi-tenant**
//!   streaming data-plane, data-parallel training orchestrator. The
//!   data-plane is session-based: one long-lived worker pool serves any
//!   number of concurrent tenants — training epochs, serving request
//!   queues, background sweeps — each opened as a
//!   `coordinator::Session` with a `JobSpec` (source, packer, shard
//!   size, ordering, `QosClass`). Worker dispatch is weighted by QoS
//!   class (default Serving 6 : Training 3 : Background 1, configurable
//!   via `PipelineConfig::qos_weights`) and every session has bounded
//!   admission credits, so a slow or abandoned consumer can never park
//!   the shared pool; buffers recycle zero-allocation through
//!   `BatchLease`s with dirty-region resets, and assembly reads an
//!   epoch-invariant prepared source (`datasets::PreparedSource`: SoA
//!   molecule arena + memoized edge topologies shared across epochs and
//!   sessions), so warm-epoch batch prep is memcpy-bound. The prepared
//!   cache also persists across *processes* (`datasets::persist`, the
//!   paper's "compressed serialized binary representation" extended to
//!   derived topology): give the plane a `cache_dir` — or build one
//!   offline with `molpack prepare` — and epoch 1 of a fresh process
//!   streams warm from a versioned, checksummed, fingerprint-validated
//!   cache file.
//!   *Migration note:* the deprecated single-tenant
//!   `DataPlane::start_epoch(epoch)` wrapper has been removed after its
//!   one promised release — use
//!   `plane.open_session(JobSpec::training(epoch))`, which streams the
//!   identical ordered batch sequence.
//! * **L2 (python/compile/model.py)** — SchNet forward/backward in JAX,
//!   AOT-lowered to HLO text artifacts at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots (RBF expansion, fused continuous-filter MLP, scatter-add
//!   as one-hot matmul), checked against a pure-jnp oracle.
//!
//! Python never runs on the training path: the Rust binary loads
//! `artifacts/*.hlo.txt` via the PJRT C API (`xla` crate) and drives the
//! whole training loop natively.

pub mod baseline;
pub mod coordinator;
pub mod datasets;
pub mod figures;
pub mod fleet;
pub mod graph;
pub mod ipu;
pub mod lint;
pub mod optim;
pub mod packing;
pub mod perfmodel;
pub mod planner;
pub mod runtime;
pub mod train;
pub mod util;
