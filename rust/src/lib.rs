//! molpack — hardware/software co-design for molecular GNN training.
//!
//! Rust reproduction of "Extreme Acceleration of Graph Neural Network-based
//! Prediction Models for Quantum Chemistry" (Graphcore/PNNL, 2022).
//!
//! Three-layer architecture:
//! * **L3 (this crate)** — coordinator: datasets, batch packing (LPFHP,
//!   sharded for incremental epoch planning), scatter/gather planner, BSP
//!   tile-machine performance model, a persistent streaming data-plane
//!   (long-lived worker pool, prefetching, zero-allocation batch
//!   recycling), data-parallel training orchestrator.
//! * **L2 (python/compile/model.py)** — SchNet forward/backward in JAX,
//!   AOT-lowered to HLO text artifacts at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot spots (RBF expansion, fused continuous-filter MLP, scatter-add
//!   as one-hot matmul), checked against a pure-jnp oracle.
//!
//! Python never runs on the training path: the Rust binary loads
//! `artifacts/*.hlo.txt` via the PJRT C API (`xla` crate) and drives the
//! whole training loop natively.

pub mod baseline;
pub mod coordinator;
pub mod datasets;
pub mod figures;
pub mod graph;
pub mod ipu;
pub mod optim;
pub mod packing;
pub mod perfmodel;
pub mod planner;
pub mod runtime;
pub mod train;
pub mod util;
