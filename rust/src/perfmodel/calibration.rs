//! Calibration check: regenerate Table 1 from the model and compare the
//! *shape* against the paper (who wins, roughly by how much, where the
//! knees fall). Run with `cargo test --release calibration -- --nocapture`
//! to print the table while tuning constants.

use crate::perfmodel::WorkloadProfile;

/// Paper Table 1 (seconds/epoch): rows QM9, 500K, 2.7M, 4.5M; columns
/// 8/16/32/64 IPUs and 8 GPUs.
pub const PAPER_TABLE1: [(&str, [f64; 4], f64); 4] = [
    ("QM9", [0.91, 0.72, 0.68, 0.9], 1.86),
    ("500K", [8.39, 5.36, 5.0, 5.57], 6.87),
    ("2.7M", [35.07, 21.37, 14.81, 11.74], 34.36),
    ("4.5M", [62.56, 35.0, 27.03, 19.38], 60.0),
];

/// Synthetic workload profiles with the paper's published statistics
/// (measured profiles from the generators are used by the figure harness;
/// these fixed ones keep calibration deterministic).
pub fn paper_profiles() -> Vec<WorkloadProfile> {
    vec![
        WorkloadProfile {
            name: "QM9".into(),
            n_graphs: 134_000,
            avg_nodes: 18.0,
            max_nodes: 29,
            avg_degree: 12.0,
            packing_efficiency: 0.97,
        },
        WorkloadProfile {
            name: "500K".into(),
            n_graphs: 500_000,
            avg_nodes: 52.0,
            max_nodes: 75,
            avg_degree: 18.0,
            packing_efficiency: 0.90,
        },
        WorkloadProfile {
            name: "2.7M".into(),
            n_graphs: 2_700_000,
            avg_nodes: 52.0,
            max_nodes: 75,
            avg_degree: 18.0,
            packing_efficiency: 0.90,
        },
        WorkloadProfile {
            name: "4.5M".into(),
            n_graphs: 4_500_000,
            avg_nodes: 60.0,
            max_nodes: 90,
            avg_degree: 20.0,
            packing_efficiency: 0.85,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{estimate_gpu_epoch, GpuArch};
    use crate::ipu::IpuArch;
    use crate::perfmodel::{estimate_epoch, OptFlags, SchNetDims, TrainSetup};

    #[test]
    fn calibration_table() {
        let ipu = IpuArch::bow();
        let gpu = GpuArch::a100();
        let model = SchNetDims::default();
        println!(
            "{:>6} | {:>8} {:>8} {:>8} {:>8} | {:>8} | paper-ipu16 paper-gpu speedup(model/paper)",
            "ds", "8", "16", "32", "64", "8GPU"
        );
        for (w, (name, paper_ipu, paper_gpu)) in
            paper_profiles().iter().zip(PAPER_TABLE1.iter())
        {
            let mut row = Vec::new();
            for r in [8usize, 16, 32, 64] {
                let e = estimate_epoch(
                    w,
                    &TrainSetup { n_ipus: r, opts: OptFlags::ALL, ..Default::default() },
                    &ipu,
                );
                row.push(e.epoch_secs);
            }
            let g = estimate_gpu_epoch(w, &model, 8, &gpu);
            let model_speedup = g.epoch_secs / row[1];
            let paper_speedup = paper_gpu / paper_ipu[1];
            println!(
                "{name:>6} | {:8.2} {:8.2} {:8.2} {:8.2} | {:8.2} | model x{model_speedup:.2} paper x{paper_speedup:.2}",
                row[0], row[1], row[2], row[3], g.epoch_secs
            );
        }
    }
}
