//! Workload profiles: the dataset statistics the performance model needs,
//! *measured* from the actual synthetic generators + the actual LPFHP
//! packer (not hardcoded), then scaled to the paper's full graph counts.

use crate::datasets::PaperDataset;
use crate::graph::radius_edges;
use crate::packing::{lpfhp, Packer};

/// Summary statistics driving the performance model.
#[derive(Debug, Clone)]
pub struct WorkloadProfile {
    pub name: String,
    /// Graphs per epoch at paper scale.
    pub n_graphs: usize,
    pub avg_nodes: f64,
    pub max_nodes: usize,
    /// Average directed degree under the radius cutoff.
    pub avg_degree: f64,
    /// Measured LPFHP node-slot utilization at s_m = max_nodes.
    pub packing_efficiency: f64,
}

impl WorkloadProfile {
    /// Measure a profile from `sample` graphs of the dataset's synthetic
    /// source, attributing the paper-scale `n_graphs` for epoch math.
    pub fn measure(ds: PaperDataset, sample: usize, r_cut: f32, seed: u64) -> WorkloadProfile {
        let src = ds.source((ds.full_len() / sample).max(1), seed);
        let n = src.len().min(sample);
        assert!(n > 0);
        let mut sizes = Vec::with_capacity(n);
        let mut edge_total = 0usize;
        let mut node_total = 0usize;
        // geometry sample for degrees (cheaper than the size column)
        let geo_stride = (n / 256).max(1);
        for i in 0..n {
            let atoms = src.n_atoms(i);
            sizes.push(atoms);
            if i % geo_stride == 0 {
                let mol = src.get(i);
                edge_total += radius_edges(&mol, r_cut).len();
                node_total += mol.n_atoms();
            }
        }
        let max_nodes = *sizes.iter().max().unwrap();
        let avg_nodes = sizes.iter().sum::<usize>() as f64 / n as f64;
        let packing = lpfhp(&sizes, max_nodes, None);
        WorkloadProfile {
            name: ds.name().to_string(),
            n_graphs: ds.full_len(),
            avg_nodes,
            max_nodes,
            avg_degree: edge_total as f64 / node_total.max(1) as f64,
            packing_efficiency: packing.efficiency(),
        }
    }

    /// Padding-baseline node-slot utilization (one graph per slot).
    pub fn padding_efficiency(&self) -> f64 {
        self.avg_nodes / self.max_nodes as f64
    }

    /// Efficiency under an arbitrary packer at pack budget `s_m`,
    /// re-measured from a fresh size sample.
    pub fn packer_efficiency(
        ds: PaperDataset,
        packer: Packer,
        s_m: usize,
        sample: usize,
        seed: u64,
    ) -> f64 {
        let src = ds.source((ds.full_len() / sample).max(1), seed);
        let n = src.len().min(sample);
        let sizes: Vec<usize> = (0..n).map(|i| src.n_atoms(i)).collect();
        packer.run(&sizes, s_m, None).efficiency()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qm9_profile_matches_paper_characterization() {
        let p = WorkloadProfile::measure(PaperDataset::Qm9, 2000, 6.0, 1);
        assert_eq!(p.n_graphs, 134_000);
        assert!(p.max_nodes <= 29);
        // paper: padding wastes ~38% on QM9 => avg/max ≈ 0.62
        let pad_eff = p.padding_efficiency();
        assert!((0.5..=0.8).contains(&pad_eff), "padding eff {pad_eff}");
        // LPFHP at s_m = max should already beat padding clearly
        assert!(p.packing_efficiency > pad_eff + 0.1);
    }

    #[test]
    fn water_profile_ranges() {
        let p = WorkloadProfile::measure(PaperDataset::Water4_5m, 2000, 6.0, 2);
        assert_eq!(p.max_nodes, 90);
        assert!((40.0..=80.0).contains(&p.avg_nodes), "avg {}", p.avg_nodes);
        assert!(p.avg_degree > 5.0 && p.avg_degree < 40.0);
        // Fig. 8: at s_m = max_nodes the 4.5M set packs to ~75-85%
        // utilization (the mode sits above half the max, so many packs
        // hold a single large cluster).
        assert!(p.packing_efficiency > 0.70, "{}", p.packing_efficiency);
    }

    #[test]
    fn subset_has_smaller_max() {
        let p = WorkloadProfile::measure(PaperDataset::Water2_7m, 1000, 6.0, 3);
        assert!(p.max_nodes <= 75);
    }

    #[test]
    fn lpfhp_beats_padding_efficiency_on_all_datasets() {
        for ds in PaperDataset::all() {
            let p = WorkloadProfile::measure(ds, 800, 6.0, 4);
            assert!(
                p.packing_efficiency >= p.padding_efficiency(),
                "{}: {} < {}",
                p.name,
                p.packing_efficiency,
                p.padding_efficiency()
            );
        }
    }
}
