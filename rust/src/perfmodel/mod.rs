//! SchNet-on-IPU performance model: composes the planner (Eqs. 8–9), the
//! collective model and host-I/O overlap into per-epoch time / throughput
//! for a dataset × replica-count × optimization-flag setting.
//!
//! This is the figure engine for Table 1 and Figs. 6/7/9/10/13. Absolute
//! times are estimates (our substrate is a model, not a Pod64 — DESIGN.md
//! §2); the *shapes* the paper reports are what the model must reproduce:
//! packing ≥ padding and growing with scale, QM9 throughput peaking at 32
//! IPUs, water clusters scaling through 64, merged collectives and
//! optimized softplus shaving per-step time, prefetch helping the big
//! dataset and hurting the small one.

pub mod calibration;
pub mod workload;

pub use workload::WorkloadProfile;

use crate::ipu::collectives::{fleet_allreduce_time, FleetAllReduceConfig};
use crate::ipu::{allreduce_time, AllReduceConfig, IpuArch};
use crate::planner::{plan_gather, plan_scatter, OpDims};

/// SchNet dimensions for the performance model (paper defaults: hidden
/// 100, 25 Gaussians, 4 interaction blocks).
#[derive(Debug, Clone, Copy)]
pub struct SchNetDims {
    pub hidden: usize,
    pub n_rbf: usize,
    pub n_interactions: usize,
}

impl Default for SchNetDims {
    fn default() -> Self {
        SchNetDims { hidden: 100, n_rbf: 25, n_interactions: 4 }
    }
}

impl SchNetDims {
    /// Approximate parameter count (embedding + blocks + readout).
    pub fn param_count(&self) -> usize {
        let f = self.hidden;
        let k = self.n_rbf;
        100 * f + self.n_interactions * (f * f + k * f + f + f * f + f + 2 * (f * f + f))
            + f * (f / 2)
            + f / 2
            + f / 2
            + 1
    }
}

/// The paper's optimization switches (Fig. 6 legend, applied left to
/// right: packing, async I/O, optimized softplus, merged all-reduce,
/// prefetch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptFlags {
    pub packing: bool,
    pub async_io: bool,
    pub opt_softplus: bool,
    pub merged_allreduce: bool,
    pub prefetch: bool,
}

impl OptFlags {
    pub const NONE: OptFlags = OptFlags {
        packing: false,
        async_io: false,
        opt_softplus: false,
        merged_allreduce: false,
        prefetch: false,
    };
    pub const ALL: OptFlags = OptFlags {
        packing: true,
        async_io: true,
        opt_softplus: true,
        merged_allreduce: true,
        prefetch: true,
    };

    /// The Fig. 6 progression: each step enables one more optimization.
    pub fn progression() -> Vec<(&'static str, OptFlags)> {
        let mut f = OptFlags::NONE;
        let mut out = vec![];
        f.packing = true;
        out.push(("Packing", f));
        f.async_io = true;
        out.push(("Async I/O", f));
        f.opt_softplus = true;
        out.push(("Opt. softplus", f));
        f.merged_allreduce = true;
        out.push(("Merged allreduce", f));
        f.prefetch = true;
        out.push(("Prefetch", f));
        out
    }
}

/// A full training setup to evaluate.
#[derive(Debug, Clone, Copy)]
pub struct TrainSetup {
    pub model: SchNetDims,
    /// Packs (or padded graph slots) per device batch.
    pub packs_per_batch: usize,
    pub n_ipus: usize,
    pub opts: OptFlags,
    /// Host-side per-graph batch preparation cost, seconds (disk decode +
    /// collation). Two-level caching is folded in here.
    pub host_prep_per_graph_s: f64,
    /// Number of asynchronous dataloader workers when async_io is on.
    pub io_workers: usize,
}

impl Default for TrainSetup {
    fn default() -> Self {
        TrainSetup {
            model: SchNetDims::default(),
            packs_per_batch: 8,
            n_ipus: 16,
            opts: OptFlags::ALL,
            host_prep_per_graph_s: 24e-6,
            io_workers: 8,
        }
    }
}

/// Model output for one (dataset, setup) evaluation.
#[derive(Debug, Clone, Copy)]
pub struct EpochEstimate {
    pub epoch_secs: f64,
    pub throughput_graphs_per_s: f64,
    pub steps_per_epoch: f64,
    pub graphs_per_step: f64,
    pub step_device_secs: f64,
    pub step_allreduce_secs: f64,
    pub step_host_secs: f64,
}

/// Matmul efficiency for dense blocks (AMP utilization on realistic tile
/// mappings; GNN workloads don't hit peak).
const MXU_UTIL: f64 = 0.15;
/// Elementwise VPU ops per element for the two softplus variants: the
/// branchy Eq. 10 form costs a select + exp + log + compare chain; the
/// branch-free Eq. 11 form vectorizes tighter.
const SOFTPLUS_OPS_NAIVE: f64 = 10.0;
const SOFTPLUS_OPS_OPT: f64 = 6.0;
/// Per-step framework overhead on device: fixed program-switch/host-sync
/// cost plus a per-node-slot program-size term (larger static batches make
/// longer compiled programs).
const STEP_OVERHEAD_BASE_S: f64 = 250e-6;
const STEP_OVERHEAD_PER_SLOT_S: f64 = 0.4e-6;
/// Per-step host round-trip latency hidden by prefetching.
const HOST_LATENCY_S: f64 = 450e-6;
/// Managing the depth-4 prefetch queue costs buffer bookkeeping + an
/// extra staging copy per step.
const PREFETCH_OVERHEAD_S: f64 = 250e-6;
/// Prefetch slots pin batch buffers; re-filling the pipeline at each epoch
/// boundary costs this many steps.
const PREFETCH_DEPTH: f64 = 4.0;

/// Estimate one epoch of data-parallel SchNet training.
pub fn estimate_epoch(
    w: &WorkloadProfile,
    setup: &TrainSetup,
    arch: &IpuArch,
) -> EpochEstimate {
    let f = setup.model.hidden as f64;
    let k = setup.model.n_rbf as f64;
    let t_blocks = setup.model.n_interactions as f64;
    let s_m = w.max_nodes as f64; // pack budget = max graph size
    let b = setup.packs_per_batch as f64;
    let r = setup.n_ipus as f64;

    // --- batch composition --------------------------------------------------
    // packing: LPFHP fills ~packing_efficiency of every node slot;
    // padding: each slot holds one graph (avg_nodes of s_m used).
    let eff = if setup.opts.packing { w.packing_efficiency } else { w.avg_nodes / s_m };
    let node_slots = b * s_m;
    let real_nodes = node_slots * eff;
    let graphs_per_step = real_nodes / w.avg_nodes;
    // static edge budget: k_max per node slot; real edges follow the data
    let edge_budget = node_slots * w.avg_degree * 1.3; // headroom like ours
    let real_edges = real_nodes * w.avg_degree;

    // --- device compute per step -------------------------------------------
    // Edge-wise dense work (filter MLP + modulation), fwd + bwd ≈ 3x fwd.
    let edge_flops = real_edges * 2.0 * (k * f + f * f + 3.0 * f) * t_blocks * 3.0;
    // Node-wise dense work runs over every slot (padding wastes it here).
    let node_flops =
        node_slots * 2.0 * (3.0 * f * f) * t_blocks * 3.0 + node_slots * 2.0 * f * (f / 2.0) * 3.0;
    let matmul_secs = (edge_flops + node_flops) / (arch.peak_flops() * MXU_UTIL);

    // Gather/scatter via the planner (2 ops per block, fwd + bwd ≈ 2x).
    let dims = OpDims {
        i: edge_budget as usize,
        m: node_slots as usize,
        n: setup.model.hidden,
    };
    let gather = plan_gather(dims, arch).cycles;
    let scatter = plan_scatter(dims, arch).cycles;
    let gs_secs = arch.cycles_to_secs((gather + scatter) * t_blocks * 2.0);

    // Softplus activations: edge budget × F per block plus node MLPs.
    let act_elems = (edge_budget * f + node_slots * f) * t_blocks * 2.0;
    let ops = if setup.opts.opt_softplus { SOFTPLUS_OPS_OPT } else { SOFTPLUS_OPS_NAIVE };
    let vpu_rate = arch.tiles as f64 * arch.clock_hz * 2.0; // elem-ops/s
    let act_secs = act_elems * ops / vpu_rate;

    let step_overhead = STEP_OVERHEAD_BASE_S + node_slots * STEP_OVERHEAD_PER_SLOT_S;
    let step_device = matmul_secs + gs_secs + act_secs + step_overhead;

    // --- gradient all-reduce -------------------------------------------------
    let step_allreduce = allreduce_time(
        AllReduceConfig {
            replicas: setup.n_ipus,
            total_bytes: 4 * setup.model.param_count(),
            n_tensors: 9 * setup.model.n_interactions + 4,
            merged: setup.opts.merged_allreduce,
        },
        arch,
    );

    // --- host I/O -------------------------------------------------------------
    // Preparing one batch costs prep_per_graph × graphs (+ packing lookup,
    // folded in). Sync loader serializes this with the device; async
    // workers divide it; prefetch hides the transfer latency.
    let prep = graphs_per_step * w.avg_nodes / 20.0 * setup.host_prep_per_graph_s;
    let host_per_step = if setup.opts.async_io {
        prep / setup.io_workers as f64
    } else {
        prep
    };
    // Prefetch (paper section 5.3.3): the queue hides host→device latency,
    // but only as much of it as the running device step can cover — with a
    // short step (QM9's s_m = 29 batches) the DMA for the depth-4 buffers
    // contends with the step itself and little latency is actually hidden,
    // while the queue bookkeeping is still paid. This is the mechanism
    // behind the paper's observation that prefetch helps 4.5M and *hurts*
    // QM9.
    let latency = if setup.opts.prefetch {
        let hidden = HOST_LATENCY_S.min(0.3 * step_device);
        HOST_LATENCY_S - hidden + PREFETCH_OVERHEAD_S
    } else {
        HOST_LATENCY_S
    };

    // --- epoch ----------------------------------------------------------------
    let graphs_per_parallel_step = graphs_per_step * r;
    let steps = (w.n_graphs as f64 / graphs_per_parallel_step).ceil();
    let device_path = step_device + step_allreduce + latency;
    // async I/O overlaps with compute; sync I/O serializes
    let step_total = if setup.opts.async_io {
        device_path.max(host_per_step) + 0.05 * host_per_step
    } else {
        device_path + host_per_step
    };
    // pipeline fill cost at epoch boundaries
    let fill = if setup.opts.prefetch { PREFETCH_DEPTH * step_total } else { 0.0 };
    // per-epoch fixed cost growing with replicas (engage/sync the pod)
    let epoch_fixed = 0.05 + 0.003 * r;

    let epoch_secs = steps * step_total + fill + epoch_fixed;
    EpochEstimate {
        epoch_secs,
        throughput_graphs_per_s: w.n_graphs as f64 / epoch_secs,
        steps_per_epoch: steps,
        graphs_per_step: graphs_per_parallel_step,
        step_device_secs: step_device,
        step_allreduce_secs: step_allreduce,
        step_host_secs: host_per_step,
    }
}

/// Model output for one fleet-scale evaluation: `planes` replicated
/// pods splitting the epoch, under the serial and overlapped collective
/// schedules. The overlap bound is the BSP one the fleet sim is
/// measured against: a stream and a collective that fully shadow each
/// other, with one exposed tail.
#[derive(Debug, Clone, Copy)]
pub struct FleetEpochEstimate {
    /// Data-parallel planes in the fleet.
    pub planes: usize,
    /// Per-plane steps in one fleet epoch.
    pub steps_per_epoch: f64,
    /// Stream wall per epoch (device compute + host I/O, no collective).
    pub epoch_stream_secs: f64,
    /// Total hierarchical collective wall per epoch.
    pub epoch_allreduce_secs: f64,
    /// Epoch wall under the serial schedule (stream + collective).
    pub epoch_secs_serial: f64,
    /// Epoch wall under the overlapped schedule
    /// (`max(stream, collective)` + one exposed tail).
    pub epoch_secs_overlapped: f64,
    /// `epoch_secs_serial / epoch_secs_overlapped` — how much of the
    /// collective the overlap hides.
    pub overlap_speedup: f64,
    /// Fleet throughput under the overlapped schedule.
    pub throughput_graphs_per_s: f64,
}

/// Estimate one epoch of fleet training: `planes` pods, each configured
/// as `setup`, splitting the dataset evenly (the shard manifest's
/// rendezvous balance) and combining gradients with the hierarchical
/// collective ([`fleet_allreduce_time`]). Built on [`estimate_epoch`]'s
/// per-step terms so the single-plane fleet agrees with the pod model.
pub fn estimate_fleet_epoch(
    w: &WorkloadProfile,
    setup: &TrainSetup,
    planes: usize,
    arch: &IpuArch,
) -> FleetEpochEstimate {
    assert!(planes >= 1, "a fleet has at least one plane");
    let base = estimate_epoch(w, setup, arch);
    let steps = (base.steps_per_epoch / planes as f64).ceil();
    let stream_step = base.step_device_secs + base.step_host_secs;
    let ar_step = fleet_allreduce_time(
        FleetAllReduceConfig {
            planes,
            replicas_per_plane: setup.n_ipus,
            total_bytes: 4 * setup.model.param_count(),
            n_tensors: 9 * setup.model.n_interactions + 4,
            merged: setup.opts.merged_allreduce,
        },
        arch,
    );
    let epoch_stream = steps * stream_step;
    let epoch_ar = steps * ar_step;
    let serial = epoch_stream + epoch_ar;
    let overlapped = epoch_stream.max(epoch_ar) + stream_step.min(ar_step);
    FleetEpochEstimate {
        planes,
        steps_per_epoch: steps,
        epoch_stream_secs: epoch_stream,
        epoch_allreduce_secs: epoch_ar,
        epoch_secs_serial: serial,
        epoch_secs_overlapped: overlapped,
        overlap_speedup: serial / overlapped,
        throughput_graphs_per_s: w.n_graphs as f64 / overlapped,
    }
}

/// Modeled seconds of stream wall per graph for one plane of a fleet
/// splitting `n_graphs` evenly — the unit cost the straggler watchdog
/// ([`fleet::watchdog`](crate::fleet::watchdog)) multiplies by a
/// member's shard-graph count to derive its drain deadline (invariant
/// F4's time base). `epoch_stream_secs` is per *plane* over `1/planes`
/// of the dataset, so per graph the fleet-wide cost is
/// `epoch_stream_secs * planes / n_graphs`.
pub fn fleet_secs_per_graph(est: &FleetEpochEstimate, n_graphs: usize) -> f64 {
    assert!(n_graphs > 0, "a deadline needs at least one graph");
    (est.epoch_stream_secs * est.planes as f64 / n_graphs as f64).max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ipu::IpuArch;

    fn qm9() -> WorkloadProfile {
        WorkloadProfile {
            name: "QM9".into(),
            n_graphs: 134_000,
            avg_nodes: 18.0,
            max_nodes: 29,
            avg_degree: 12.0,
            packing_efficiency: 0.98,
        }
    }

    fn water45() -> WorkloadProfile {
        WorkloadProfile {
            name: "4.5M".into(),
            n_graphs: 4_500_000,
            avg_nodes: 60.0,
            max_nodes: 90,
            avg_degree: 14.0,
            packing_efficiency: 0.97,
        }
    }

    fn setup(n_ipus: usize, opts: OptFlags) -> TrainSetup {
        TrainSetup { n_ipus, opts, ..Default::default() }
    }

    #[test]
    fn secs_per_graph_is_positive_and_scale_consistent() {
        let arch = IpuArch::bow();
        let w = water45();
        let s = setup(16, OptFlags::ALL);
        let one = estimate_fleet_epoch(&w, &s, 1, &arch);
        let spg = fleet_secs_per_graph(&one, w.n_graphs);
        assert!(spg > 0.0 && spg.is_finite());
        // One plane streaming the whole dataset: per-graph cost times
        // graph count reproduces the epoch stream wall.
        assert!((spg * w.n_graphs as f64 - one.epoch_stream_secs).abs() < 1e-9);
        // More planes split the same stream work: the per-graph unit
        // cost stays within the rounding slack of one step.
        let four = estimate_fleet_epoch(&w, &s, 4, &arch);
        let spg4 = fleet_secs_per_graph(&four, w.n_graphs);
        assert!((spg4 - spg).abs() / spg < 0.01, "unit cost is plane-count invariant");
    }

    #[test]
    fn packing_beats_padding_everywhere() {
        let arch = IpuArch::bow();
        for w in [qm9(), water45()] {
            for r in [1, 8, 16, 32, 64] {
                let mut pad = OptFlags::ALL;
                pad.packing = false;
                let tp_pack = estimate_epoch(&w, &setup(r, OptFlags::ALL), &arch)
                    .throughput_graphs_per_s;
                let tp_pad =
                    estimate_epoch(&w, &setup(r, pad), &arch).throughput_graphs_per_s;
                assert!(
                    tp_pack >= tp_pad,
                    "{} at {r} IPUs: pack {tp_pack} < pad {tp_pad}",
                    w.name
                );
            }
        }
    }

    #[test]
    fn each_fig6_optimization_helps_water() {
        // Fig. 6: progressive optimizations each improve (prefetch may
        // regress QM9 but helps the 4.5M set).
        let arch = IpuArch::bow();
        let w = water45();
        let mut last = f64::INFINITY;
        for (name, opts) in OptFlags::progression() {
            let e = estimate_epoch(&w, &setup(16, opts), &arch).epoch_secs;
            assert!(e <= last * 1.001, "{name} regressed: {e} > {last}");
            last = e;
        }
    }

    #[test]
    fn prefetch_hurts_qm9_but_helps_water() {
        // Paper section 5.3.3 (Fig. 6, 16 IPUs): prefetching improves the
        // 4.5M water set but negatively impacts QM9.
        let arch = IpuArch::bow();
        let mut no_pf = OptFlags::ALL;
        no_pf.prefetch = false;
        let delta = |w: &WorkloadProfile| {
            let with = estimate_epoch(w, &setup(16, OptFlags::ALL), &arch).epoch_secs;
            let without = estimate_epoch(w, &setup(16, no_pf), &arch).epoch_secs;
            without - with // positive = prefetch helps
        };
        assert!(delta(&qm9()) < 0.0, "prefetch should cost QM9");
        assert!(delta(&water45()) > 0.0, "prefetch should help 4.5M");
    }

    #[test]
    fn qm9_throughput_peaks_before_64() {
        // Paper Fig. 9 / Table 1: QM9 peaks at 16-32 IPUs then degrades.
        let arch = IpuArch::bow();
        let w = qm9();
        let tp: Vec<f64> = [8usize, 16, 32, 64]
            .iter()
            .map(|&r| estimate_epoch(&w, &setup(r, OptFlags::ALL), &arch).throughput_graphs_per_s)
            .collect();
        let peak = tp
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(peak == 1 || peak == 2, "peak at index {peak}, tp={tp:?}");
        assert!(tp[3] < tp[peak], "64 IPUs should be past the knee");
    }

    #[test]
    fn water_scales_through_64() {
        // Paper Fig. 9: 2.7M/4.5M keep gaining up to 64 IPUs.
        let arch = IpuArch::bow();
        let w = water45();
        let mut last = 0.0;
        for r in [8usize, 16, 32, 64] {
            let tp = estimate_epoch(&w, &setup(r, OptFlags::ALL), &arch)
                .throughput_graphs_per_s;
            assert!(tp > last, "throughput must grow at {r} IPUs");
            last = tp;
        }
    }

    #[test]
    fn merged_allreduce_helps_more_at_scale() {
        let arch = IpuArch::bow();
        let w = water45();
        let gain = |r: usize| {
            let mut un = OptFlags::ALL;
            un.merged_allreduce = false;
            let a = estimate_epoch(&w, &setup(r, OptFlags::ALL), &arch).epoch_secs;
            let b = estimate_epoch(&w, &setup(r, un), &arch).epoch_secs;
            b / a
        };
        assert!(gain(64) > gain(2));
    }

    #[test]
    fn bigger_model_costs_more() {
        // Fig. 10: epoch time grows with embedding size and blocks.
        let arch = IpuArch::bow();
        let w = water45();
        let mut s = setup(16, OptFlags::ALL);
        let base = estimate_epoch(&w, &s, &arch).epoch_secs;
        s.model.hidden = 256;
        let wide = estimate_epoch(&w, &s, &arch).epoch_secs;
        s.model.hidden = 100;
        s.model.n_interactions = 8;
        let deep = estimate_epoch(&w, &s, &arch).epoch_secs;
        assert!(wide > base && deep > base);
    }

    #[test]
    fn fleet_epochs_shrink_with_planes_and_overlap_hides_the_collective() {
        let arch = IpuArch::bow();
        let w = water45();
        let s = setup(16, OptFlags::ALL);
        let one = estimate_fleet_epoch(&w, &s, 1, &arch);
        let four = estimate_fleet_epoch(&w, &s, 4, &arch);
        // more planes -> fewer per-plane steps -> shorter epochs, even
        // though each collective now crosses host links
        assert!(four.epoch_secs_serial < one.epoch_secs_serial);
        assert!(four.steps_per_epoch < one.steps_per_epoch);
        // overlap never loses, and strictly wins whenever there is a
        // collective to hide
        for planes in [1usize, 2, 4, 8] {
            let e = estimate_fleet_epoch(&w, &s, planes, &arch);
            assert!(e.overlap_speedup >= 1.0, "{planes} planes");
            assert!(e.epoch_secs_overlapped <= e.epoch_secs_serial);
            assert!(
                e.epoch_secs_overlapped
                    >= e.epoch_stream_secs.max(e.epoch_allreduce_secs) - 1e-12,
                "overlap cannot beat the BSP bound at {planes} planes"
            );
        }
    }
}
