//! Host-I/O pipeline bench (paper section 4.2.3 / Fig. 7b): batch
//! preparation throughput for the sync baseline vs multi-worker async
//! loading, the effect of prefetch depth, and the two-level cache hit
//! behavior over the disk store — the latter through a persistent
//! `DataPlane` held across epochs. `cargo bench --bench bench_loader`.

use std::sync::Arc;

use molpack::coordinator::{stream_epoch, Batcher, DataPlane, JobSpec, PipelineConfig};
use molpack::datasets::{write_store, CachedSource, HydroNet, MoleculeSource, Store};
use molpack::runtime::BatchGeometry;

fn geometry() -> BatchGeometry {
    BatchGeometry {
        n_nodes: 384,
        n_edges: 4608,
        n_graphs: 48,
        packs_per_batch: 4,
        nodes_per_pack: 96,
        edges_per_pack: 1152,
        graphs_per_pack: 12,
    }
}

fn bench_pipeline<S: MoleculeSource + 'static>(src: Arc<S>, workers: usize, depth: usize) -> (f64, usize) {
    let batcher = Batcher::new(geometry(), 6.0);
    let cfg = PipelineConfig { workers, prefetch_depth: depth, ..Default::default() };
    let t0 = std::time::Instant::now();
    let mut graphs = 0;
    for b in stream_epoch(src, batcher, &cfg, 0) {
        graphs += b.unwrap().real_graphs();
    }
    (t0.elapsed().as_secs_f64(), graphs)
}

fn main() {
    let n = 3000;
    println!("loader benchmark — {n} water clusters per epoch\n");

    // (a) sync vs async workers (generator-backed source)
    println!("{:>8} {:>7} | {:>9} {:>11}", "workers", "depth", "secs", "graphs/s");
    for workers in [1usize, 2, 4, 8] {
        let src = Arc::new(HydroNet::new(n, 1));
        let (secs, graphs) = bench_pipeline(src, workers, 4);
        println!(
            "{:>8} {:>7} | {:>9.2} {:>11.0}",
            workers,
            4,
            secs,
            graphs as f64 / secs
        );
    }

    // (b) prefetch depth sweep
    for depth in [1usize, 2, 4, 8] {
        let src = Arc::new(HydroNet::new(n, 1));
        let (secs, graphs) = bench_pipeline(src, 4, depth);
        println!(
            "{:>8} {:>7} | {:>9.2} {:>11.0}",
            4,
            depth,
            secs,
            graphs as f64 / secs
        );
    }

    // (c) disk store + two-level cache: hit rate across epochs, streamed
    // through one persistent data-plane (workers and buffers reused)
    let dir = std::env::temp_dir().join("molpack-bench");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.mpks");
    let gen = HydroNet::new(1000, 2);
    let mols: Vec<_> = (0..1000).map(|i| gen.get(i)).collect();
    write_store(&path, &mols).unwrap();
    let cached = Arc::new(CachedSource::new(Store::open(&path).unwrap(), 1000));
    let plane = DataPlane::new(
        Arc::clone(&cached),
        Batcher::new(geometry(), 6.0),
        PipelineConfig { workers: 4, prefetch_depth: 4, ..Default::default() },
    );
    println!("\ndisk store + LRU cache (capacity = dataset), persistent plane:");
    for epoch in 0..3 {
        let t0 = std::time::Instant::now();
        let mut graphs = 0;
        for b in plane.open_session(JobSpec::training(epoch)) {
            graphs += b.unwrap().real_graphs();
        }
        let stats = cached.stats();
        println!(
            "  epoch {epoch}: {:.2}s, {graphs} graphs, cumulative hit rate {:.1}%, buffers {}",
            t0.elapsed().as_secs_f64(),
            stats.hit_rate() * 100.0,
            plane.buffers_allocated()
        );
    }
    std::fs::remove_file(&path).ok();
    println!("\nbench_loader OK");
}
