//! End-to-end table/figure regeneration bench: produces every model-driven
//! exhibit of the paper's evaluation (Table 1 and Figs. 6/7/9/10/12) in
//! one run — the `cargo bench` entry point that corresponds to "run the
//! paper's evaluation section". Figs. 5/8 (data-driven) are in
//! `molpack figures` / `examples/packing_analysis`; Fig. 11 (real
//! training) is `examples/train_hydronet`.

use molpack::figures;

fn main() {
    let t0 = std::time::Instant::now();
    for (name, text) in [
        ("fig6", figures::fig6()),
        ("fig7", figures::fig7()),
        ("fig9", figures::fig9()),
        ("fig10", figures::fig10()),
        ("fig12", figures::fig12()),
        ("table1+fig13", figures::table1()),
    ] {
        println!("===== {name} =====\n{text}\n");
    }
    println!(
        "bench_tables OK ({:.1}s for all model-driven exhibits)",
        t0.elapsed().as_secs_f64()
    );
}
