//! Data-plane bench: first-batch latency, steady-state throughput,
//! mixed-tenancy QoS, and cold-vs-warm assembly of the persistent
//! streaming pipeline. `cargo bench --bench bench_pipeline`.
//!
//! What it demonstrates:
//! * first-batch latency tracks the *shard* size, not the dataset size —
//!   a 10× larger synthetic HydroNet must stay within 2× at a fixed
//!   shard, while whole-dataset planning (shard 0) degrades ~linearly;
//! * steady-state batches/sec vs worker count through one persistent
//!   plane, compared against the per-epoch rebuild path (`stream_epoch`,
//!   the seed architecture's cost model);
//! * mixed tenancy (ISSUE 3): one Training + one Serving session
//!   sharing a plane, consumed concurrently, reporting per-class p95
//!   dispatcher queue wait — the Serving class must not see its tail
//!   latency destroyed by a Training epoch in flight;
//! * cold vs warm assembly (ISSUE 4): the same epoch replayed on one
//!   plane, with the epoch-invariant prepared source (SoA arena + edge
//!   cache) warm on the second pass — asserted ≥ 2× throughput,
//!   bitwise-identical stream, zero warm misses — written as
//!   machine-readable `BENCH_assembly.json` for the perf trajectory;
//! * persistence (ISSUE 5): cold epoch 1 on a fresh plane vs epoch 1 on
//!   a *second* fresh plane that restores the persisted prepared cache
//!   from disk (two independent planes share no in-memory state — the
//!   fresh-process proxy) — asserted ≥ 1.5× epoch-1 speedup,
//!   bitwise-identical stream, zero molecule/edge recomputation —
//!   written as `BENCH_persist.json`.
//!
//! * zero-copy mapped load (ISSUE 7): epoch 1 restored from the same
//!   cache file via `MapMode::Mapped` (the file *is* the arena) vs
//!   `MapMode::Owned` (bulk read) — asserted ≥ 1.2× and
//!   bitwise-identical, with a two-plane RSS check that mapped planes
//!   share page-cache pages — written as `BENCH_mmap.json`; plus the
//!   `fill_pack` u8→i32 widen micro-bench.
//!
//! * SLO-guarded overload (ISSUE 10): one Serving session driven at
//!   ~2× its sustainable consumption rate. Unguarded, the dispatcher
//!   queue wait diverges — per-quarter p95 grows monotonically.
//!   Guarded by an `Slo` deadline, the gate sheds predicted-miss
//!   batches: served p95 stays under the deadline while `shed > 0`.
//!   The request `Coalescer` is then held against the whole-mix
//!   training LPFHP pack fill on the same molecule sizes (asserted
//!   ≥ 0.8×) — written as `BENCH_slo.json` (the fill rates are
//!   deterministic and guarded; wall-clock waits are informational).
//!
//! Flags (after `--`): `--assembly-only` / `--persist-only` /
//! `--mmap-only` / `--widen-only` / `--slo-only` run a single section
//! (the `make bench-smoke` CI entry points); `--graphs N` sizes their
//! dataset; `--out PATH` / `--persist-out PATH` / `--mmap-out PATH` /
//! `--slo-out PATH` move the JSON (defaults `BENCH_assembly.json` /
//! `BENCH_persist.json` / `BENCH_mmap.json` / `BENCH_slo.json`).

use std::sync::Arc;
use std::time::Instant;

use molpack::coordinator::{
    stream_epoch, widen_u8_to_i32, Batcher, Coalescer, DataPlane, JobSpec, PipelineConfig, Slo,
    SloConfig,
};
use molpack::datasets::{HydroNet, MapMode, MoleculeSource, PreparedSource, CACHE_FILE};
use molpack::packing::{pack_shard, Packer};
use molpack::runtime::{BatchGeometry, HostBatch};
use molpack::util::stats::summarize;

fn geometry() -> BatchGeometry {
    BatchGeometry {
        n_nodes: 384,
        n_edges: 4608,
        n_graphs: 48,
        packs_per_batch: 4,
        nodes_per_pack: 96,
        edges_per_pack: 1152,
        graphs_per_pack: 12,
    }
}

/// Seconds from session open to the first delivered batch (min of `reps`).
fn first_batch_secs(n: usize, shard_size: usize, reps: usize) -> f64 {
    let plane = DataPlane::new(
        Arc::new(HydroNet::new(n, 1)),
        Batcher::new(geometry(), 6.0),
        PipelineConfig { workers: 2, shard_size, ..Default::default() },
    );
    let mut best = f64::INFINITY;
    for epoch in 0..reps as u64 {
        let t0 = Instant::now();
        let mut stream = plane.open_session(JobSpec::training(epoch));
        let first = stream.next().expect("session yields batches").expect("assembly ok");
        let dt = t0.elapsed().as_secs_f64();
        drop(first);
        stream.cancel();
        best = best.min(dt);
    }
    best
}

/// Mixed tenancy: one Training and one Serving session stream
/// concurrently from one plane (each consumed on its own thread, with a
/// small per-batch consumer delay standing in for device time). Returns
/// per-class (p50, p95) dispatcher queue waits in ms.
fn mixed_tenancy(workers: usize, n_train: usize, n_serve: usize) -> [(f64, f64); 2] {
    let plane = DataPlane::new(
        Arc::new(HydroNet::new(n_train, 1)),
        Batcher::new(geometry(), 6.0),
        PipelineConfig { workers, shard_size: 512, ..Default::default() },
    );
    let serve_src = Arc::new(HydroNet::new(n_serve, 2));
    fn consume(mut s: molpack::coordinator::Session) -> (usize, Vec<f64>) {
        let mut graphs = 0usize;
        for b in s.by_ref() {
            graphs += b.expect("assembly ok").real_graphs();
            // stand-in for a device step: without it the consumer
            // outruns assembly and queue waits are all ~0
            std::thread::sleep(std::time::Duration::from_micros(300));
        }
        (graphs, s.queue_wait_samples_ms())
    }
    std::thread::scope(|scope| {
        let train_session = plane.open_session(JobSpec::training(0));
        let serve_session = plane
            .open_session(JobSpec::serving().with_source(serve_src).with_credits(2));
        let t = scope.spawn(move || consume(train_session));
        let s = scope.spawn(move || consume(serve_session));
        let (tg, tw) = t.join().expect("training consumer");
        let (sg, sw) = s.join().expect("serving consumer");
        assert_eq!(tg, n_train, "training session lost graphs");
        assert_eq!(sg, n_serve, "serving session lost graphs");
        let t_sum = summarize(&tw);
        let s_sum = summarize(&sw);
        [(s_sum.p50, s_sum.p95), (t_sum.p50, t_sum.p95)]
    })
}

/// One full epoch pass over `plane`: wall seconds, graphs streamed, and
/// a per-batch content fingerprint (bit patterns, so "bitwise-identical"
/// means exactly that).
fn epoch_pass(plane: &DataPlane, epoch: u64) -> (f64, usize, Vec<u64>) {
    fn fingerprint(b: &HostBatch) -> u64 {
        // FNV-1a over every tensor's bit pattern — cheap relative to
        // assembly, sensitive to any byte-level divergence.
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        };
        b.z.iter().for_each(|&x| eat(x as u64));
        b.pos.iter().for_each(|&x| eat(x.to_bits() as u64));
        b.src.iter().for_each(|&x| eat(x as u64));
        b.dst.iter().for_each(|&x| eat(x as u64));
        b.edge_mask.iter().for_each(|&x| eat(x.to_bits() as u64));
        b.graph_id.iter().for_each(|&x| eat(x as u64));
        b.node_mask.iter().for_each(|&x| eat(x.to_bits() as u64));
        b.target.iter().for_each(|&x| eat(x.to_bits() as u64));
        b.graph_mask.iter().for_each(|&x| eat(x.to_bits() as u64));
        h
    }
    let t0 = Instant::now();
    let mut graphs = 0usize;
    let mut prints = Vec::new();
    for lease in plane.open_session(JobSpec::training(epoch)) {
        let b = lease.expect("assembly ok");
        graphs += b.real_graphs();
        prints.push(fingerprint(&b));
    }
    (t0.elapsed().as_secs_f64(), graphs, prints)
}

/// Cold-vs-warm assembly over the synthetic 500K-subset size profile
/// (clusters capped at 25 waters / 75 atoms, the paper's 500K shape).
/// Replays the same epoch so the plans are identical and the only
/// difference is the prepared-source temperature. Writes
/// `BENCH_assembly.json` and asserts the ISSUE 4 acceptance bars.
fn assembly_cold_vs_warm(n: usize, workers: usize, out: &str) {
    println!("assembly cold vs warm — synthetic 500K subset, {n} graphs, {workers} workers:");
    let plane = DataPlane::new(
        Arc::new(HydroNet::with_max_molecules(n, 1, 25)),
        Batcher::new(geometry(), 6.0),
        PipelineConfig { workers, shard_size: 2048, ..Default::default() },
    );
    let (cold_secs, cold_graphs, cold_prints) = epoch_pass(&plane, 0);
    let cold_stats = plane.prepared_stats();
    let (warm_secs, warm_graphs, warm_prints) = epoch_pass(&plane, 0);
    let warm_stats = plane.prepared_stats();

    assert_eq!(cold_graphs, n, "cold epoch lost graphs");
    assert_eq!(warm_graphs, n, "warm epoch lost graphs");
    assert_eq!(cold_prints, warm_prints, "warm stream is not bitwise-identical to cold");
    let warm_misses = warm_stats.edge_misses - cold_stats.edge_misses;
    assert_eq!(warm_misses, 0, "warm epoch recomputed {warm_misses} edge lists");
    let speedup = cold_secs / warm_secs;
    let cold_gps = cold_graphs as f64 / cold_secs;
    let warm_gps = warm_graphs as f64 / warm_secs;
    println!("  cold epoch: {cold_secs:>7.3}s  {cold_gps:>9.0} graphs/s");
    println!("  warm epoch: {warm_secs:>7.3}s  {warm_gps:>9.0} graphs/s");
    println!(
        "  speedup {speedup:.2}x | arena {:.1} MB in {} segments | edge cache {:.1} MB, {} entries, warm hit rate {:.3}",
        warm_stats.arena_bytes as f64 / 1e6,
        warm_stats.segments_built,
        warm_stats.edge_bytes as f64 / 1e6,
        warm_stats.edge_entries,
        warm_stats.edge_hit_rate(),
    );
    assert!(
        speedup >= 2.0,
        "warm-epoch assembly must be >= 2x cold ({speedup:.2}x)"
    );

    let fields = [
        "  \"bench\": \"assembly_cold_vs_warm\"".to_string(),
        "  \"dataset\": \"synthetic-500K-subset\"".to_string(),
        format!("  \"graphs\": {n}"),
        format!("  \"workers\": {workers}"),
        format!("  \"cold_secs\": {cold_secs:.6}"),
        format!("  \"warm_secs\": {warm_secs:.6}"),
        format!("  \"cold_graphs_per_sec\": {cold_gps:.1}"),
        format!("  \"warm_graphs_per_sec\": {warm_gps:.1}"),
        format!("  \"speedup\": {speedup:.3}"),
        "  \"bitwise_identical\": true".to_string(),
        format!("  \"warm_edge_misses\": {warm_misses}"),
        format!("  \"arena_bytes\": {}", warm_stats.arena_bytes),
        format!("  \"arena_segments\": {}", warm_stats.segments_built),
        format!("  \"edge_cache_bytes\": {}", warm_stats.edge_bytes),
        format!("  \"edge_cache_entries\": {}", warm_stats.edge_entries),
        format!("  \"buffers_allocated\": {}", plane.buffers_allocated()),
    ];
    let json = format!("{{\n{}\n}}\n", fields.join(",\n"));
    std::fs::write(out, json).expect("writing assembly bench JSON");
    println!("  wrote {out}");
}

/// Persistence: fresh-process epoch 1, cold vs warm-from-disk (ISSUE 5
/// acceptance). Plane A pays the cold epoch and persists the prepared
/// cache; plane B — constructed from scratch, sharing no in-memory state
/// with A, the in-harness proxy for a fresh `serve`/`train` process —
/// restores it from disk and replays the same epoch. Asserts ≥ 1.5×
/// epoch-1 speedup, a bitwise-identical batch stream, and zero
/// recomputation; writes `BENCH_persist.json`.
fn persist_cold_vs_warm(n: usize, workers: usize, out: &str) {
    println!("persist: fresh-process epoch 1, cold vs warm-from-disk — {n} graphs, {workers} workers:");
    let dir = std::env::temp_dir().join(format!("molpack-bench-persist-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("creating bench cache dir");
    std::fs::remove_file(dir.join(CACHE_FILE)).ok(); // always start cold
    let mk_plane = || {
        DataPlane::new(
            Arc::new(HydroNet::with_max_molecules(n, 1, 25)),
            Batcher::new(geometry(), 6.0),
            PipelineConfig {
                workers,
                shard_size: 2048,
                cache_dir: Some(dir.clone()),
                ..Default::default()
            },
        )
    };

    let cold_plane = mk_plane();
    assert!(
        !cold_plane.prepared_stats().loaded_from_disk,
        "cold plane unexpectedly found a cache"
    );
    let (cold_secs, cold_graphs, cold_prints) = epoch_pass(&cold_plane, 0);
    let t0 = Instant::now();
    let persist_bytes = cold_plane
        .save_prepared()
        .expect("persisting prepared cache")
        .expect("cache_dir is configured");
    let save_secs = t0.elapsed().as_secs_f64();
    drop(cold_plane);

    let t0 = Instant::now();
    let warm_plane = mk_plane();
    let load_secs = t0.elapsed().as_secs_f64();
    let loaded = warm_plane.prepared_stats();
    assert!(loaded.loaded_from_disk, "fresh plane failed to restore the disk cache");
    let (warm_secs, warm_graphs, warm_prints) = epoch_pass(&warm_plane, 0);
    let warm_stats = warm_plane.prepared_stats();

    assert_eq!(cold_graphs, n, "cold epoch lost graphs");
    assert_eq!(warm_graphs, n, "warm epoch lost graphs");
    assert_eq!(
        cold_prints, warm_prints,
        "warm-from-disk stream is not bitwise-identical to cold"
    );
    assert_eq!(warm_stats.edge_misses, 0, "warm-from-disk epoch recomputed edge lists");
    assert_eq!(warm_stats.molecule_misses, 0, "warm-from-disk epoch materialized molecules");
    let speedup = cold_secs / warm_secs;
    let cold_gps = cold_graphs as f64 / cold_secs;
    let warm_gps = warm_graphs as f64 / warm_secs;
    println!("  cold epoch 1 (no cache):  {cold_secs:>7.3}s  {cold_gps:>9.0} graphs/s");
    println!("  warm epoch 1 (from disk): {warm_secs:>7.3}s  {warm_gps:>9.0} graphs/s");
    println!(
        "  speedup {speedup:.2}x | cache file {:.1} MB (save {save_secs:.2}s, load+fingerprint {load_secs:.3}s)",
        persist_bytes as f64 / 1e6,
    );
    assert!(
        speedup >= 1.5,
        "warm-from-disk epoch 1 must be >= 1.5x cold ({speedup:.2}x)"
    );

    let fields = [
        "  \"bench\": \"persist_cold_vs_warm\"".to_string(),
        "  \"dataset\": \"synthetic-500K-subset\"".to_string(),
        format!("  \"graphs\": {n}"),
        format!("  \"workers\": {workers}"),
        format!("  \"cold_epoch1_secs\": {cold_secs:.6}"),
        format!("  \"warm_epoch1_secs\": {warm_secs:.6}"),
        format!("  \"cold_graphs_per_sec\": {cold_gps:.1}"),
        format!("  \"warm_graphs_per_sec\": {warm_gps:.1}"),
        format!("  \"speedup\": {speedup:.3}"),
        "  \"bitwise_identical\": true".to_string(),
        format!("  \"cache_file_bytes\": {persist_bytes}"),
        format!("  \"save_secs\": {save_secs:.6}"),
        format!("  \"load_secs\": {load_secs:.6}"),
        format!("  \"warm_edge_misses\": {}", warm_stats.edge_misses),
        format!("  \"warm_molecule_misses\": {}", warm_stats.molecule_misses),
        format!("  \"arena_bytes\": {}", warm_stats.arena_bytes),
        format!("  \"edge_cache_bytes\": {}", warm_stats.edge_bytes),
    ];
    let json = format!("{{\n{}\n}}\n", fields.join(",\n"));
    std::fs::write(out, json).expect("writing persist bench JSON");
    println!("  wrote {out}");
    drop(warm_plane);
    std::fs::remove_dir_all(&dir).ok();
}

/// Linux resident-set size in bytes from `/proc/self/status`, when the
/// proc filesystem exists (None elsewhere — the RSS assertion is skipped).
fn rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Epoch-1 style touch of every byte a training epoch reads — molecule
/// tensors plus the `(6.0, 12)` edge topology — folded into one FNV-1a
/// fingerprint so "bitwise-identical across load modes" is literal.
fn prepared_epoch_fingerprint(prep: &PreparedSource) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x100000001b3);
    };
    let topo = prep.topology(6.0, 12);
    for i in 0..prep.len() {
        let m = prep.molecule(i);
        m.z.iter().for_each(|&x| eat(x as u64));
        m.pos.iter().for_each(|&x| eat(x.to_bits() as u64));
        eat(m.energy.to_bits() as u64);
        let (e, _) = prep.edges(&topo, i);
        e.src.iter().for_each(|&x| eat(x as u64));
        e.dst.iter().for_each(|&x| eat(x as u64));
    }
    h
}

/// Zero-copy mmap load (ISSUE 7 acceptance): epoch 1 on a plane that
/// memory-maps the cache vs one that bulk-reads it into an owned arena.
/// Same file, same stream — the only difference is `MapMode`. Asserts
/// mapped >= 1.2x owned and a bitwise-identical stream, then checks that
/// a *second* mapped plane shares page-cache pages instead of paying a
/// second resident copy. Writes `BENCH_mmap.json`.
fn persist_mmap(n: usize, out: &str) {
    println!("persist-mmap: epoch 1, mapped vs owned cache load — {n} graphs:");
    let dir = std::env::temp_dir().join(format!("molpack-bench-mmap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("creating bench cache dir");
    let path = dir.join(CACHE_FILE);
    std::fs::remove_file(&path).ok(); // always start from a fresh file

    let source: Arc<dyn MoleculeSource> = Arc::new(HydroNet::with_max_molecules(n, 1, 25));
    let builder = PreparedSource::new(Arc::clone(&source));
    builder.warm(6.0, 12);
    let file_bytes = builder.save(&path).expect("persisting bench cache");
    drop(builder);

    // Interleave the modes rep by rep so page-cache temperature and CPU
    // clocks are shared fairly; keep the min per mode.
    let reps = 3;
    let mut best = [f64::INFINITY; 2]; // [owned, mapped]
    let mut prints = [0u64; 2];
    for _ in 0..reps {
        for (slot, mode) in [(0, MapMode::Owned), (1, MapMode::Mapped)] {
            let t0 = Instant::now();
            let prep = PreparedSource::load_with(Arc::clone(&source), &path, mode)
                .expect("bench cache loads");
            prints[slot] = prepared_epoch_fingerprint(&prep);
            best[slot] = best[slot].min(t0.elapsed().as_secs_f64());
            assert_eq!(prep.stats().map_fallbacks, 0, "bench cache hit a lazy fallback");
        }
    }
    let [owned_secs, mapped_secs] = best;
    assert_eq!(
        prints[0], prints[1],
        "mapped load is not bitwise-identical to owned load"
    );
    let speedup = owned_secs / mapped_secs;
    println!("  owned  load + epoch-1 touch: {owned_secs:>8.4}s");
    println!("  mapped load + epoch-1 touch: {mapped_secs:>8.4}s");
    println!(
        "  mapped over owned {speedup:.2}x | cache file {:.1} MB",
        file_bytes as f64 / 1e6
    );
    if molpack::util::mmap::SUPPORTED {
        assert!(
            speedup >= 1.2,
            "mapped epoch-1 load must be >= 1.2x owned ({speedup:.2}x)"
        );
    } else {
        println!("  (mmap unsupported on this platform — Mapped fell back to a bulk read)");
    }

    // Page sharing: with one mapped plane resident, a second mapped
    // plane over the same file must not pay a second copy of the data —
    // its RSS growth stays well under the file size because both map the
    // same page-cache pages.
    let mut rss_shared_fraction = -1.0f64;
    if molpack::util::mmap::SUPPORTED && rss_bytes().is_some() {
        let a = PreparedSource::load_with(Arc::clone(&source), &path, MapMode::Mapped)
            .expect("bench cache loads");
        prepared_epoch_fingerprint(&a); // fault every page in
        let rss_one = rss_bytes().expect("proc rss");
        let b = PreparedSource::load_with(Arc::clone(&source), &path, MapMode::Mapped)
            .expect("bench cache loads");
        prepared_epoch_fingerprint(&b);
        let rss_two = rss_bytes().expect("proc rss");
        let delta = rss_two.saturating_sub(rss_one);
        rss_shared_fraction = 1.0 - delta as f64 / file_bytes as f64;
        println!(
            "  second mapped plane RSS delta: {:.1} MB over a {:.1} MB file ({:.0}% shared)",
            delta as f64 / 1e6,
            file_bytes as f64 / 1e6,
            100.0 * rss_shared_fraction,
        );
        // The second plane re-faults shared pages (no new physical
        // copy) plus its own edge-slot bookkeeping; half the file
        // size is a generous ceiling that still catches an
        // accidental owned-copy regression.
        assert!(
            (delta as f64) < 0.5 * file_bytes as f64,
            "second mapped plane grew RSS by {delta} bytes (file is {file_bytes}) — \
             pages are not being shared"
        );
    }

    let fields = [
        "  \"bench\": \"persist_mmap\"".to_string(),
        "  \"dataset\": \"synthetic-500K-subset\"".to_string(),
        format!("  \"graphs\": {n}"),
        format!("  \"owned_load_secs\": {owned_secs:.6}"),
        format!("  \"mapped_load_secs\": {mapped_secs:.6}"),
        format!("  \"mapped_over_owned_speedup\": {speedup:.3}"),
        "  \"bitwise_identical\": true".to_string(),
        format!("  \"cache_file_bytes\": {file_bytes}"),
        format!("  \"rss_shared_fraction\": {rss_shared_fraction:.3}"),
    ];
    let json = format!("{{\n{}\n}}\n", fields.join(",\n"));
    std::fs::write(out, json).expect("writing mmap bench JSON");
    println!("  wrote {out}");
    std::fs::remove_dir_all(&dir).ok();
}

/// One overload serving pass: eager whole-dataset planning (every
/// request enqueued up front — the open-loop overload model), a
/// consumer that sleeps `delay_us` per served batch, an optional SLO on
/// the session. Returns (served-batch queue waits in sample order, shed
/// batches, served batches).
fn overload_pass(n: usize, workers: usize, delay_us: u64, slo: Option<Slo>) -> (Vec<f64>, u64, u64) {
    let plane = DataPlane::new(
        Arc::new(HydroNet::new(n, 1)),
        Batcher::new(geometry(), 6.0),
        // shard_size 0 = eager planning: the whole request queue is in
        // the Serving lane at t=0, so backlog growth is pure overload
        PipelineConfig { workers, shard_size: 0, ..Default::default() },
    );
    let mut spec = JobSpec::serving().with_credits(4);
    if let Some(s) = slo {
        spec = spec.with_slo(s);
    }
    let mut session = plane.open_session(spec);
    let mut served = 0u64;
    let mut shed = 0u64;
    for lease in session.by_ref() {
        match lease {
            Ok(b) => {
                drop(b);
                served += 1;
                // the 2x-sustainable device stand-in: consumption is the
                // bottleneck, so the lane backlog grows
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
            Err(e) if e.to_string().starts_with("shed:") => shed += 1,
            Err(e) => panic!("overload pass failed: {e}"),
        }
    }
    let waits = session.queue_wait_samples_ms();
    let m = session.metrics();
    assert_eq!(m.shed, shed, "consumer-counted sheds must match session metrics");
    (waits, shed, served)
}

/// SLO-guarded overload + request coalescing (ISSUE 10 acceptance).
/// Calibrates the sustainable serving rate, drives the session at ~2×
/// that, and contrasts unguarded divergence with SLO-guarded shedding;
/// then packs a single-molecule request stream through the `Coalescer`
/// and holds its fill rate against the whole-mix training LPFHP pack.
/// Writes `BENCH_slo.json`.
fn slo_overload(n: usize, workers: usize, out: &str) {
    println!("slo overload — {n} serving requests, {workers} workers:");
    let t_section = Instant::now();

    // (1) calibrate: an unthrottled consumer bounds the sustainable
    // per-batch service time on this machine.
    let t0 = Instant::now();
    let (_, _, cal_batches) = overload_pass(n, workers, 0, None);
    let sustain_us = (t0.elapsed().as_micros() as u64 / cal_batches.max(1)).max(150);
    // ~2x sustainable load: the consumer takes twice as long per batch
    // as the plane needs to produce one.
    let delay_us = sustain_us * 2;
    println!(
        "  sustainable ~{sustain_us} us/batch over {cal_batches} batches; overload consumer at {delay_us} us/batch"
    );

    // (2) unguarded: the queue wait diverges — each quarter of the run
    // waits strictly longer than the one before it.
    let (waits, shed0, _) = overload_pass(n, workers, delay_us, None);
    assert_eq!(shed0, 0, "no SLO, nothing to shed");
    let q = waits.len() / 4;
    assert!(q >= 4, "need >= 16 batches for quarter percentiles, got {}", waits.len());
    let quarters: Vec<f64> = (0..4).map(|i| summarize(&waits[i * q..(i + 1) * q]).p95).collect();
    println!(
        "  unguarded queue-wait p95 by quarter: {:.2} / {:.2} / {:.2} / {:.2} ms",
        quarters[0], quarters[1], quarters[2], quarters[3]
    );
    for w in quarters.windows(2) {
        assert!(
            w[1] > w[0],
            "unguarded overload must diverge monotonically ({quarters:?})"
        );
    }
    let divergence = quarters[3] / quarters[0].max(1e-9);

    // (3) guarded: a deadline of ~20 consumer steps. Served batches
    // structurally meet it (the gate dispatches nothing older), the
    // rest of the backlog is shed instead of queueing unboundedly.
    let deadline_ms = delay_us as f64 / 1000.0 * 20.0;
    let (gwaits, shed, served) = overload_pass(n, workers, delay_us, Some(Slo::deadline(deadline_ms)));
    let gp95 = if gwaits.is_empty() { 0.0 } else { summarize(&gwaits).p95 };
    println!(
        "  guarded ({deadline_ms:.1} ms deadline): served {served} (wait p95 {gp95:.2} ms), shed {shed}"
    );
    assert!(shed > 0, "2x overload must shed under a {deadline_ms:.1} ms deadline");
    assert!(
        gp95 <= deadline_ms * 1.05,
        "served p95 {gp95:.2} ms breaches the {deadline_ms:.1} ms deadline"
    );

    // (4) request coalescing: single-molecule requests arriving on a
    // virtual clock, packed by the same LPFHP machinery as training.
    // Deterministic, so the fill rates are guarded ledger metrics.
    let g = geometry();
    let src = HydroNet::new(n, 7);
    let sizes: Vec<usize> = (0..src.len()).map(|i| src.n_atoms(i)).collect();
    let ids: Vec<u32> = (0..sizes.len() as u32).collect();
    let whole = pack_shard(Packer::Lpfhp, &ids, &sizes, g.nodes_per_pack, Some(g.graphs_per_pack));
    let real_nodes: usize = sizes.iter().sum();
    let train_fill = real_nodes as f64 / (whole.n_packs() * g.nodes_per_pack) as f64;
    let cfg = SloConfig::default();
    let mut coalescer = Coalescer::new(&cfg, g.nodes_per_pack, Some(g.graphs_per_pack));
    let mut packed_items = 0usize;
    let mut drain = |p: Option<molpack::packing::Packing>| {
        if let Some(p) = p {
            packed_items += p.packs.iter().map(|k| k.items.len()).sum::<usize>();
        }
    };
    // deterministic arrival schedule: one request every 0.1 virtual ms
    // against the config's flush horizon
    for (i, &s) in sizes.iter().enumerate() {
        let now_ms = i as f64 * 0.1;
        drain(coalescer.submit(i as u32, s, now_ms));
        drain(coalescer.poll(now_ms));
    }
    drain(coalescer.flush());
    assert_eq!(packed_items, sizes.len(), "coalescer lost or duplicated requests");
    let coalesce_fill = coalescer.efficiency();
    let vs_training = coalesce_fill / train_fill;
    let (_, flushes, packs) = coalescer.counts();
    println!(
        "  coalescer: {flushes} flushes, {packs} packs, fill {coalesce_fill:.3} vs whole-mix training {train_fill:.3} ({vs_training:.2}x)"
    );
    assert!(
        vs_training >= 0.8,
        "coalesced packs must reach >= 0.8x the training fill ({vs_training:.2}x)"
    );

    let wall = t_section.elapsed().as_secs_f64();
    let fields = [
        "  \"bench\": \"slo_overload\"".to_string(),
        format!("  \"graphs\": {n}"),
        format!("  \"workers\": {workers}"),
        // deterministic pack-fill rates: the guarded metrics
        format!("  \"coalesce_fill_hit_rate\": {coalesce_fill:.6}"),
        format!("  \"coalesce_vs_training_hit_rate\": {vs_training:.6}"),
        // wall-clock shedding behavior: machine-dependent, informational
        // (the hard bars are asserted above, not diffed)
        format!("  \"deadline_budget\": {deadline_ms:.3}"),
        format!("  \"unguarded_q1_p95_wait\": {:.3}", quarters[0]),
        format!("  \"unguarded_q4_p95_wait\": {:.3}", quarters[3]),
        format!("  \"unguarded_divergence\": {divergence:.3}"),
        format!("  \"guarded_p95_wait\": {gp95:.3}"),
        format!("  \"shed_batches\": {shed}"),
        format!("  \"served_batches\": {served}"),
        format!("  \"wall_time\": {wall:.6}"),
    ];
    let json = format!("{{\n{}\n}}\n", fields.join(",\n"));
    std::fs::write(out, json).expect("writing slo bench JSON");
    println!("  wrote {out}");
}

/// Micro-bench for the `fill_pack` z-widen: the unit-stride
/// `widen_u8_to_i32` block loop vs the naive scalar loop, over a
/// batch-sized span repeated enough to be timeable. Correctness is
/// asserted; throughput is reported (the block loop autovectorizes to
/// `pmovzxbd`-class code, the scalar loop may not).
fn widen_micro() {
    println!("widen micro-bench — fill_pack u8 -> i32 z-widen:");
    let len = 96 * 1024; // many pack-sized rows
    let src: Vec<u8> = (0..len).map(|i| (i * 7 + 3) as u8).collect();
    let mut out = vec![0i32; len];
    let mut scalar = vec![0i32; len];
    let reps = 2000;

    let t0 = Instant::now();
    for _ in 0..reps {
        for (o, &s) in scalar.iter_mut().zip(&src) {
            *o = i32::from(s);
        }
        std::hint::black_box(&scalar);
    }
    let scalar_secs = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for _ in 0..reps {
        widen_u8_to_i32(&src, &mut out);
        std::hint::black_box(&out);
    }
    let widen_secs = t0.elapsed().as_secs_f64();

    assert_eq!(out, scalar, "widen_u8_to_i32 diverged from the scalar loop");
    let bytes = (len * reps) as f64;
    println!(
        "  scalar loop: {:>8.1} MB/s | widen_u8_to_i32: {:>8.1} MB/s ({:.2}x)",
        bytes / scalar_secs / 1e6,
        bytes / widen_secs / 1e6,
        scalar_secs / widen_secs,
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let flag_val = |key: &str| {
        args.iter()
            .position(|a| a == key)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let out = flag_val("--out").unwrap_or_else(|| "BENCH_assembly.json".to_string());
    let persist_out =
        flag_val("--persist-out").unwrap_or_else(|| "BENCH_persist.json".to_string());
    let mmap_out = flag_val("--mmap-out").unwrap_or_else(|| "BENCH_mmap.json".to_string());
    let slo_out = flag_val("--slo-out").unwrap_or_else(|| "BENCH_slo.json".to_string());
    let assembly_graphs: usize = flag_val("--graphs")
        .map(|v| v.parse().expect("--graphs takes an integer"))
        .unwrap_or(20_000);
    if args.iter().any(|a| a == "--assembly-only") {
        // CI smoke entry point (`make bench-smoke`): just the ISSUE 4
        // acceptance section on a CI-sized dataset.
        assembly_cold_vs_warm(assembly_graphs, 4, &out);
        println!("\nbench_pipeline assembly smoke OK");
        return;
    }
    if args.iter().any(|a| a == "--persist-only") {
        // CI smoke entry point (`make bench-smoke`): just the ISSUE 5
        // fresh-process persistence section on a CI-sized dataset.
        persist_cold_vs_warm(assembly_graphs, 4, &persist_out);
        println!("\nbench_pipeline persist smoke OK");
        return;
    }
    if args.iter().any(|a| a == "--mmap-only") {
        // CI smoke entry point (`make bench-smoke`): just the ISSUE 7
        // zero-copy mapped-load section on a CI-sized dataset.
        persist_mmap(assembly_graphs, &mmap_out);
        println!("\nbench_pipeline mmap smoke OK");
        return;
    }
    if args.iter().any(|a| a == "--widen-only") {
        widen_micro();
        println!("\nbench_pipeline widen micro OK");
        return;
    }
    if args.iter().any(|a| a == "--slo-only") {
        // CI smoke entry point (`make bench-smoke` via `make slo`): the
        // ISSUE 10 overload + coalescing section on a CI-sized queue.
        slo_overload(assembly_graphs, 2, &slo_out);
        println!("\nbench_pipeline slo smoke OK");
        return;
    }

    println!("data-plane benchmark\n");

    // (a) first-batch latency: sharded planning must scale with the
    // shard, not the dataset
    const SHARD: usize = 2048;
    println!(
        "{:>10} {:>9} | {:>14} {:>16}",
        "graphs", "shard", "first batch ms", "(shard=0, eager)"
    );
    let mut fixed_shard = Vec::new();
    for n in [10_000usize, 100_000] {
        let sharded = first_batch_secs(n, SHARD, 3);
        let eager = first_batch_secs(n, 0, 1);
        fixed_shard.push(sharded);
        println!(
            "{:>10} {:>9} | {:>14.1} {:>16.1}",
            n,
            SHARD,
            sharded * 1e3,
            eager * 1e3
        );
    }
    let ratio = fixed_shard[1] / fixed_shard[0];
    println!("fixed-shard latency ratio 100k/10k: {ratio:.2}x");
    assert!(
        ratio <= 2.0,
        "first-batch latency must track shard size, not dataset size ({ratio:.2}x)"
    );

    // (b) steady-state throughput vs worker count: persistent plane vs
    // the per-epoch rebuild wrapper (the seed architecture)
    let n = 6000;
    println!("\n{n} graphs/epoch, 2 epochs each:");
    println!(
        "{:>8} | {:>13} {:>13} | {:>13}",
        "workers", "plane b/s", "rebuild b/s", "plane buffers"
    );
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { workers, ..Default::default() };

        let plane = DataPlane::new(
            Arc::new(HydroNet::new(n, 1)),
            Batcher::new(geometry(), 6.0),
            cfg.clone(),
        );
        let t0 = Instant::now();
        let mut batches = 0usize;
        for epoch in 0..2u64 {
            for b in plane.open_session(JobSpec::training(epoch)) {
                b.unwrap();
                batches += 1;
            }
        }
        let plane_bps = batches as f64 / t0.elapsed().as_secs_f64();
        let buffers = plane.buffers_allocated();
        drop(plane);

        let t0 = Instant::now();
        let mut rebuilt = 0usize;
        for epoch in 0..2u64 {
            let src = Arc::new(HydroNet::new(n, 1));
            for b in stream_epoch(src, Batcher::new(geometry(), 6.0), &cfg, epoch) {
                b.unwrap();
                rebuilt += 1;
            }
        }
        let rebuild_bps = rebuilt as f64 / t0.elapsed().as_secs_f64();

        println!(
            "{workers:>8} | {plane_bps:>13.1} {rebuild_bps:>13.1} | {buffers:>13}"
        );
    }

    // (c) mixed tenancy: Training + Serving sessions sharing one plane.
    // Dispatcher queue wait is the QoS signal: the Serving class runs at
    // 6:3 weight over Training, so its p95 should stay in the same
    // ballpark as Training's despite the epoch streaming concurrently.
    println!("\nmixed tenancy (training 4000 graphs + serving 1000 graphs, one plane):");
    println!("{:>8} | {:>20} | {:>20}", "workers", "serving wait p50/p95", "training wait p50/p95");
    for workers in [2usize, 4] {
        let [(sp50, sp95), (tp50, tp95)] = mixed_tenancy(workers, 4000, 1000);
        println!(
            "{workers:>8} | {:>9.3} / {:>8.3} | {:>9.3} / {:>8.3}",
            sp50, sp95, tp50, tp95
        );
    }

    // (d) epoch-invariant assembly cache: cold vs warm epoch on one
    // plane (ISSUE 4 acceptance: >= 2x, bitwise-identical, no warm
    // recomputation). Emits BENCH_assembly.json.
    println!();
    assembly_cold_vs_warm(assembly_graphs, 4, &out);

    // (e) persistent prepared cache: fresh-process epoch 1, cold vs
    // warm-from-disk (ISSUE 5 acceptance: >= 1.5x, bitwise-identical,
    // zero recomputation). Emits BENCH_persist.json.
    println!();
    persist_cold_vs_warm(assembly_graphs, 4, &persist_out);

    // (f) zero-copy mapped load: mapped vs owned epoch-1 restore off the
    // same cache file, plus the two-plane page-sharing check (ISSUE 7
    // acceptance: >= 1.2x, bitwise-identical). Emits BENCH_mmap.json.
    println!();
    persist_mmap(assembly_graphs, &mmap_out);

    // (g) SLO-guarded overload + request coalescing (ISSUE 10
    // acceptance: unguarded p95 diverges, guarded p95 <= deadline with
    // shed > 0, coalesced fill >= 0.8x training). Emits BENCH_slo.json.
    println!();
    slo_overload(4000, 2, &slo_out);

    // (h) the fill_pack z-widen micro-bench rides along — it is cheap
    // and keeps the block loop's scalar-equivalence asserted in CI.
    println!();
    widen_micro();

    println!("\nbench_pipeline OK");
}
