//! Data-plane bench: first-batch latency, steady-state throughput, and
//! mixed-tenancy QoS of the persistent streaming pipeline.
//! `cargo bench --bench bench_pipeline`.
//!
//! What it demonstrates:
//! * first-batch latency tracks the *shard* size, not the dataset size —
//!   a 10× larger synthetic HydroNet must stay within 2× at a fixed
//!   shard, while whole-dataset planning (shard 0) degrades ~linearly;
//! * steady-state batches/sec vs worker count through one persistent
//!   plane, compared against the per-epoch rebuild path (`stream_epoch`,
//!   the seed architecture's cost model);
//! * mixed tenancy (ISSUE 3): one Training + one Serving session
//!   sharing a plane, consumed concurrently, reporting per-class p95
//!   dispatcher queue wait — the Serving class must not see its tail
//!   latency destroyed by a Training epoch in flight.

use std::sync::Arc;
use std::time::Instant;

use molpack::coordinator::{stream_epoch, Batcher, DataPlane, JobSpec, PipelineConfig};
use molpack::datasets::HydroNet;
use molpack::runtime::BatchGeometry;
use molpack::util::stats::summarize;

fn geometry() -> BatchGeometry {
    BatchGeometry {
        n_nodes: 384,
        n_edges: 4608,
        n_graphs: 48,
        packs_per_batch: 4,
        nodes_per_pack: 96,
        edges_per_pack: 1152,
        graphs_per_pack: 12,
    }
}

/// Seconds from session open to the first delivered batch (min of `reps`).
fn first_batch_secs(n: usize, shard_size: usize, reps: usize) -> f64 {
    let plane = DataPlane::new(
        Arc::new(HydroNet::new(n, 1)),
        Batcher::new(geometry(), 6.0),
        PipelineConfig { workers: 2, shard_size, ..Default::default() },
    );
    let mut best = f64::INFINITY;
    for epoch in 0..reps as u64 {
        let t0 = Instant::now();
        let mut stream = plane.open_session(JobSpec::training(epoch));
        let first = stream.next().expect("session yields batches").expect("assembly ok");
        let dt = t0.elapsed().as_secs_f64();
        drop(first);
        stream.cancel();
        best = best.min(dt);
    }
    best
}

/// Mixed tenancy: one Training and one Serving session stream
/// concurrently from one plane (each consumed on its own thread, with a
/// small per-batch consumer delay standing in for device time). Returns
/// per-class (p50, p95) dispatcher queue waits in ms.
fn mixed_tenancy(workers: usize, n_train: usize, n_serve: usize) -> [(f64, f64); 2] {
    let plane = DataPlane::new(
        Arc::new(HydroNet::new(n_train, 1)),
        Batcher::new(geometry(), 6.0),
        PipelineConfig { workers, shard_size: 512, ..Default::default() },
    );
    let serve_src = Arc::new(HydroNet::new(n_serve, 2));
    fn consume(mut s: molpack::coordinator::Session) -> (usize, Vec<f64>) {
        let mut graphs = 0usize;
        for b in s.by_ref() {
            graphs += b.expect("assembly ok").real_graphs();
            // stand-in for a device step: without it the consumer
            // outruns assembly and queue waits are all ~0
            std::thread::sleep(std::time::Duration::from_micros(300));
        }
        (graphs, s.queue_wait_samples_ms())
    }
    std::thread::scope(|scope| {
        let train_session = plane.open_session(JobSpec::training(0));
        let serve_session = plane
            .open_session(JobSpec::serving().with_source(serve_src).with_credits(2));
        let t = scope.spawn(move || consume(train_session));
        let s = scope.spawn(move || consume(serve_session));
        let (tg, tw) = t.join().expect("training consumer");
        let (sg, sw) = s.join().expect("serving consumer");
        assert_eq!(tg, n_train, "training session lost graphs");
        assert_eq!(sg, n_serve, "serving session lost graphs");
        let t_sum = summarize(&tw);
        let s_sum = summarize(&sw);
        [(s_sum.p50, s_sum.p95), (t_sum.p50, t_sum.p95)]
    })
}

fn main() {
    println!("data-plane benchmark\n");

    // (a) first-batch latency: sharded planning must scale with the
    // shard, not the dataset
    const SHARD: usize = 2048;
    println!(
        "{:>10} {:>9} | {:>14} {:>16}",
        "graphs", "shard", "first batch ms", "(shard=0, eager)"
    );
    let mut fixed_shard = Vec::new();
    for n in [10_000usize, 100_000] {
        let sharded = first_batch_secs(n, SHARD, 3);
        let eager = first_batch_secs(n, 0, 1);
        fixed_shard.push(sharded);
        println!(
            "{:>10} {:>9} | {:>14.1} {:>16.1}",
            n,
            SHARD,
            sharded * 1e3,
            eager * 1e3
        );
    }
    let ratio = fixed_shard[1] / fixed_shard[0];
    println!("fixed-shard latency ratio 100k/10k: {ratio:.2}x");
    assert!(
        ratio <= 2.0,
        "first-batch latency must track shard size, not dataset size ({ratio:.2}x)"
    );

    // (b) steady-state throughput vs worker count: persistent plane vs
    // the per-epoch rebuild wrapper (the seed architecture)
    let n = 6000;
    println!("\n{n} graphs/epoch, 2 epochs each:");
    println!(
        "{:>8} | {:>13} {:>13} | {:>13}",
        "workers", "plane b/s", "rebuild b/s", "plane buffers"
    );
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { workers, ..Default::default() };

        let plane = DataPlane::new(
            Arc::new(HydroNet::new(n, 1)),
            Batcher::new(geometry(), 6.0),
            cfg.clone(),
        );
        let t0 = Instant::now();
        let mut batches = 0usize;
        for epoch in 0..2u64 {
            for b in plane.open_session(JobSpec::training(epoch)) {
                b.unwrap();
                batches += 1;
            }
        }
        let plane_bps = batches as f64 / t0.elapsed().as_secs_f64();
        let buffers = plane.buffers_allocated();
        drop(plane);

        let t0 = Instant::now();
        let mut rebuilt = 0usize;
        for epoch in 0..2u64 {
            let src = Arc::new(HydroNet::new(n, 1));
            for b in stream_epoch(src, Batcher::new(geometry(), 6.0), &cfg, epoch) {
                b.unwrap();
                rebuilt += 1;
            }
        }
        let rebuild_bps = rebuilt as f64 / t0.elapsed().as_secs_f64();

        println!(
            "{workers:>8} | {plane_bps:>13.1} {rebuild_bps:>13.1} | {buffers:>13}"
        );
    }

    // (c) mixed tenancy: Training + Serving sessions sharing one plane.
    // Dispatcher queue wait is the QoS signal: the Serving class runs at
    // 6:3 weight over Training, so its p95 should stay in the same
    // ballpark as Training's despite the epoch streaming concurrently.
    println!("\nmixed tenancy (training 4000 graphs + serving 1000 graphs, one plane):");
    println!("{:>8} | {:>20} | {:>20}", "workers", "serving wait p50/p95", "training wait p50/p95");
    for workers in [2usize, 4] {
        let [(sp50, sp95), (tp50, tp95)] = mixed_tenancy(workers, 4000, 1000);
        println!(
            "{workers:>8} | {:>9.3} / {:>8.3} | {:>9.3} / {:>8.3}",
            sp50, sp95, tp50, tp95
        );
    }

    println!("\nbench_pipeline OK");
}
