//! Data-plane bench: first-batch latency and steady-state throughput of
//! the persistent streaming pipeline. `cargo bench --bench bench_pipeline`.
//!
//! What it demonstrates (ISSUE 2 acceptance criteria):
//! * first-batch latency tracks the *shard* size, not the dataset size —
//!   a 10× larger synthetic HydroNet must stay within 2× at a fixed
//!   shard, while whole-dataset planning (shard 0) degrades ~linearly;
//! * steady-state batches/sec vs worker count through one persistent
//!   plane, compared against the per-epoch rebuild path (`stream_epoch`,
//!   the seed architecture's cost model).

use std::sync::Arc;
use std::time::Instant;

use molpack::coordinator::{stream_epoch, Batcher, DataPlane, PipelineConfig};
use molpack::datasets::HydroNet;
use molpack::runtime::BatchGeometry;

fn geometry() -> BatchGeometry {
    BatchGeometry {
        n_nodes: 384,
        n_edges: 4608,
        n_graphs: 48,
        packs_per_batch: 4,
        nodes_per_pack: 96,
        edges_per_pack: 1152,
        graphs_per_pack: 12,
    }
}

/// Seconds from `start_epoch` to the first delivered batch (min of `reps`).
fn first_batch_secs(n: usize, shard_size: usize, reps: usize) -> f64 {
    let plane = DataPlane::new(
        Arc::new(HydroNet::new(n, 1)),
        Batcher::new(geometry(), 6.0),
        PipelineConfig { workers: 2, shard_size, ..Default::default() },
    );
    let mut best = f64::INFINITY;
    for epoch in 0..reps as u64 {
        let t0 = Instant::now();
        let mut stream = plane.start_epoch(epoch);
        let first = stream.next().expect("epoch yields batches").expect("assembly ok");
        let dt = t0.elapsed().as_secs_f64();
        drop(first);
        stream.cancel();
        best = best.min(dt);
    }
    best
}

fn main() {
    println!("data-plane benchmark\n");

    // (a) first-batch latency: sharded planning must scale with the
    // shard, not the dataset
    const SHARD: usize = 2048;
    println!(
        "{:>10} {:>9} | {:>14} {:>16}",
        "graphs", "shard", "first batch ms", "(shard=0, eager)"
    );
    let mut fixed_shard = Vec::new();
    for n in [10_000usize, 100_000] {
        let sharded = first_batch_secs(n, SHARD, 3);
        let eager = first_batch_secs(n, 0, 1);
        fixed_shard.push(sharded);
        println!(
            "{:>10} {:>9} | {:>14.1} {:>16.1}",
            n,
            SHARD,
            sharded * 1e3,
            eager * 1e3
        );
    }
    let ratio = fixed_shard[1] / fixed_shard[0];
    println!("fixed-shard latency ratio 100k/10k: {ratio:.2}x");
    assert!(
        ratio <= 2.0,
        "first-batch latency must track shard size, not dataset size ({ratio:.2}x)"
    );

    // (b) steady-state throughput vs worker count: persistent plane vs
    // the per-epoch rebuild wrapper (the seed architecture)
    let n = 6000;
    println!("\n{n} graphs/epoch, 2 epochs each:");
    println!(
        "{:>8} | {:>13} {:>13} | {:>13}",
        "workers", "plane b/s", "rebuild b/s", "plane buffers"
    );
    for workers in [1usize, 2, 4, 8] {
        let cfg = PipelineConfig { workers, ..Default::default() };

        let plane = DataPlane::new(
            Arc::new(HydroNet::new(n, 1)),
            Batcher::new(geometry(), 6.0),
            cfg.clone(),
        );
        let t0 = Instant::now();
        let mut batches = 0usize;
        for epoch in 0..2u64 {
            for b in plane.start_epoch(epoch) {
                b.unwrap();
                batches += 1;
            }
        }
        let plane_bps = batches as f64 / t0.elapsed().as_secs_f64();
        let buffers = plane.buffers_allocated();
        drop(plane);

        let t0 = Instant::now();
        let mut rebuilt = 0usize;
        for epoch in 0..2u64 {
            let src = Arc::new(HydroNet::new(n, 1));
            for b in stream_epoch(src, Batcher::new(geometry(), 6.0), &cfg, epoch) {
                b.unwrap();
                rebuilt += 1;
            }
        }
        let rebuild_bps = rebuilt as f64 / t0.elapsed().as_secs_f64();

        println!(
            "{workers:>8} | {plane_bps:>13.1} {rebuild_bps:>13.1} | {buffers:>13}"
        );
    }

    println!("\nbench_pipeline OK");
}
