//! L3 hot-path bench: real PJRT train-step latency through the AOT
//! artifacts, broken into marshal / execute / readback, plus the predict
//! path. Skips gracefully when `make artifacts` hasn't run.
//! `cargo bench --bench bench_train_step`.

use std::sync::Arc;

use molpack::coordinator::{plan_epoch, Batcher, PipelineConfig};
use molpack::datasets::{HydroNet, PreparedSource};
use molpack::runtime::Engine;
use molpack::util::stats::{summarize, time_it};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        println!("bench_train_step SKIPPED: run `make artifacts` first");
        return;
    }
    let engine = Engine::load(dir).unwrap();
    let g = engine.manifest.batch;
    println!(
        "train-step benchmark — batch(N={}, E={}, G={}), params={}\n",
        g.n_nodes, g.n_edges, g.n_graphs, engine.manifest.param_count
    );

    // assemble one real packed batch
    let source = Arc::new(HydroNet::new(64, 5));
    let batcher = Batcher::new(g, engine.manifest.model.r_cut as f32);
    let plan = plan_epoch(source.as_ref(), &batcher, &PipelineConfig::default(), 0);
    let prepared = PreparedSource::new(source);
    let batch = batcher.assemble(&plan[0], &prepared).unwrap();
    println!(
        "batch: {} graphs, {} real nodes ({:.0}% of slots), {} real edges",
        batch.real_graphs(),
        batch.real_nodes(),
        100.0 * batch.real_nodes() as f64 / g.n_nodes as f64,
        batch.real_edges()
    );

    let mut state = engine.init_state().unwrap();
    let times = time_it(
        || {
            engine.train_step(&mut state, &batch).unwrap();
        },
        3,
        20,
    );
    let s = summarize(&times);
    println!(
        "\ntrain_step ms: mean {:.1} p50 {:.1} p95 {:.1} (throughput {:.1} graphs/s)",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3,
        batch.real_graphs() as f64 / s.mean
    );
    let es = engine.stats();
    println!(
        "breakdown/step: marshal {:.3} ms | execute {:.1} ms | readback {:.3} ms",
        1e3 * es.marshal_secs / es.steps as f64,
        1e3 * es.execute_secs / es.steps as f64,
        1e3 * es.readback_secs / es.steps as f64,
    );

    let times = time_it(
        || {
            engine.predict(&state.params, &batch).unwrap();
        },
        3,
        20,
    );
    let s = summarize(&times);
    println!(
        "predict    ms: mean {:.1} p50 {:.1} p95 {:.1}",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3
    );

    // batch assembly cost (the host-side hot path the pipeline overlaps);
    // the prepared source is warm after the first call, so this measures
    // the steady-state memcpy-bound path
    let times = time_it(
        || {
            batcher.assemble(&plan[0], &prepared).unwrap();
        },
        3,
        30,
    );
    let s = summarize(&times);
    println!(
        "assemble   ms: mean {:.2} p50 {:.2} p95 {:.2} (warm arena + edge cache)",
        s.mean * 1e3,
        s.p50 * 1e3,
        s.p95 * 1e3
    );
    println!("\nbench_train_step OK");
}
