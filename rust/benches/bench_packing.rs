//! Packing bench (paper section 4.1 / Fig. 8 support): algorithm latency
//! and packing quality for LPFHP vs the classic baselines over real
//! dataset size columns. `cargo bench --bench bench_packing`.
//!
//! LPFHP's selling point is histogram-level complexity: throughput
//! (graphs/s packed) should stay ~flat as the sample grows, while FFD/BFD
//! degrade.

use molpack::datasets::PaperDataset;
use molpack::packing::Packer;
use molpack::util::stats::{summarize, time_it};

fn main() {
    println!("packer benchmark — latency + quality\n");
    println!(
        "{:>6} {:>8} {:>10} | {:>10} {:>12} {:>10}",
        "ds", "graphs", "packer", "ms/run", "graphs/ms", "padding%"
    );
    for ds in [PaperDataset::Qm9, PaperDataset::Water4_5m] {
        for sample in [10_000usize, 100_000] {
            let src = ds.source((ds.full_len() / sample).max(1), 3);
            let n = src.len().min(sample);
            let sizes: Vec<usize> = (0..n).map(|i| src.n_atoms(i)).collect();
            let max = *sizes.iter().max().unwrap();
            for p in [
                Packer::NextFit,
                Packer::FirstFitDecreasing,
                Packer::BestFitDecreasing,
                Packer::Lpfhp,
            ] {
                // FFD/BFD are O(n^2)-ish with our simple pack scan; cap them
                let iters = if sample > 10_000 && p != Packer::Lpfhp && p != Packer::NextFit {
                    1
                } else {
                    5
                };
                let mut padding = 0.0;
                let times = time_it(
                    || {
                        let packing = p.run(&sizes, max, None);
                        padding = packing.padding_fraction();
                    },
                    1,
                    iters,
                );
                let s = summarize(&times);
                println!(
                    "{:>6} {:>8} {:>10} | {:>10.2} {:>12.0} {:>9.2}%",
                    ds.name(),
                    n,
                    p.name(),
                    s.p50 * 1e3,
                    n as f64 / (s.p50 * 1e3),
                    padding * 100.0
                );
            }
        }
    }
    println!("\nbench_packing OK");
}
