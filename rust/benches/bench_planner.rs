//! Scatter/gather planner bench (paper section 4.2.2): plan-search latency
//! (it runs on the host at compile time, but must stay interactive) and
//! the quality of the chosen plans vs naive partitionings across the op
//! shapes SchNet produces. `cargo bench --bench bench_planner`.

use molpack::ipu::IpuArch;
use molpack::planner::{gather_cost, plan_gather, plan_scatter, OpDims, PartitionFactors};
use molpack::util::stats::{summarize, time_it};

fn main() {
    let arch = IpuArch::bow();
    println!("planner benchmark\n");
    println!(
        "{:>22} | {:>9} {:>9} | {:>12} {:>12} {:>9}",
        "op dims (I,M,N)", "plan ms", "factors", "plan cycles", "unit cycles", "speedup"
    );
    for dims in [
        OpDims { i: 1152, m: 96, n: 64 },    // one pack, small model
        OpDims { i: 4608, m: 384, n: 64 },   // default batch
        OpDims { i: 4608, m: 384, n: 100 },  // paper hidden=100
        OpDims { i: 36_864, m: 3072, n: 128 }, // big batch, wide model
        OpDims { i: 147_456, m: 12_288, n: 256 }, // stress
    ] {
        let mut plan = plan_gather(dims, &arch);
        let times = time_it(|| plan = plan_gather(dims, &arch), 1, 5);
        let s = summarize(&times);
        let unit = gather_cost(dims, PartitionFactors::UNIT, &arch);
        println!(
            "{:>22} | {:>9.2} {:>3},{:>3},{:>2} | {:>12.0} {:>12.0} {:>8.1}x",
            format!("({},{},{})", dims.i, dims.m, dims.n),
            s.p50 * 1e3,
            plan.factors.p_i,
            plan.factors.p_m,
            plan.factors.p_n,
            plan.cycles,
            unit,
            unit / plan.cycles
        );
        // scatter plan sanity at the same dims
        let sp = plan_scatter(dims, &arch);
        assert!(sp.cycles.is_finite());
    }
    println!("\nbench_planner OK");
}
