//! E2E validation run (paper Fig. 11 analogue): train the real
//! AOT-compiled SchNet on a synthetic HydroNet corpus through the full
//! stack — sharded LPFHP planning, the persistent multi-worker
//! data-plane (each epoch a Training-class session with admission
//! credits and batch recycling), PJRT CPU execution — and print the
//! per-epoch MSE loss curve, throughput, and per-session data-plane
//! metrics. Recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_hydronet -- [graphs] [epochs] [cache_dir]
//! ```
//!
//! With a `cache_dir`, the first run persists the prepared cache
//! (molecule arena + edge topology) on exit and every later run starts
//! epoch 1 warm from disk — the fresh-process cold epoch disappears.

use std::sync::Arc;

use anyhow::Result;
use molpack::coordinator::PipelineConfig;
use molpack::datasets::HydroNet;
use molpack::packing::Packer;
use molpack::runtime::Engine;
use molpack::train::{train, TrainConfig};
use molpack::util::plot::line_chart;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let graphs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(1500);
    let epochs: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let cache_dir = args.get(2).map(std::path::PathBuf::from);

    let engine = Engine::load("artifacts")?;
    let g = engine.manifest.batch;
    println!(
        "train_hydronet: {graphs} water clusters, {epochs} epochs, batch(N={}, E={}, G={}), platform={}",
        g.n_nodes,
        g.n_edges,
        g.n_graphs,
        engine.platform()
    );

    let source = Arc::new(HydroNet::new(graphs, 2024));
    let mut state = engine.init_state()?;
    let cfg = TrainConfig {
        epochs,
        pipeline: PipelineConfig {
            workers: 3,
            prefetch_depth: 4,
            packer: Packer::Lpfhp,
            shuffle_seed: 7,
            ordered: true,
            // plan incrementally: first batch ready after packing 512
            // graphs, not the whole corpus
            shard_size: 512,
            // persist/restore the prepared cache so re-runs skip the
            // cold epoch entirely
            cache_dir,
            ..Default::default()
        },
        max_batches_per_epoch: 0,
        log_every: 0,
        overlap_epochs: true,
    };

    let records = train(&engine, &mut state, source, &cfg, |_, _, _| {})?;

    println!("\nepoch | mean MSE | batches | graphs/s | secs | wait ms | stalls");
    for r in &records {
        // `wait ms` is the epoch session's mean dispatcher queue wait;
        // `stalls` counts admission-credit hits (nonzero = the device,
        // not the data-plane, bounded the epoch — the healthy state).
        println!(
            "{:5} | {:8.5} | {:7} | {:8.1} | {:6.2} | {:7.3} | {:6}",
            r.epoch, r.mean_loss, r.batches, r.graphs_per_sec, r.secs, r.queue_wait_ms, r.credit_stalls
        );
    }

    let x: Vec<f64> = records.iter().map(|r| r.epoch as f64).collect();
    let y: Vec<f64> = records.iter().map(|r| r.mean_loss.ln()).collect();
    println!("\n{}", line_chart("log mean MSE per epoch (Fig. 11 analogue)", &x, &[("log-loss", y)], 50, 12));

    let s = engine.stats();
    println!(
        "engine profile: {} steps | execute {:.1} ms/step | marshal {:.3} ms/step | readback {:.3} ms/step",
        s.steps,
        1e3 * s.execute_secs / s.steps.max(1) as f64,
        1e3 * s.marshal_secs / s.steps.max(1) as f64,
        1e3 * s.readback_secs / s.steps.max(1) as f64,
    );

    let first = records.first().unwrap().mean_loss;
    let last = records.last().unwrap().mean_loss;
    println!("\nloss {first:.4} -> {last:.4} ({}x reduction)", (first / last) as i64);
    assert!(last < first, "training must reduce the loss");
    println!("train_hydronet OK");
    Ok(())
}
