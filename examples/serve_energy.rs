//! Serving path: load trained (or initial) parameters and serve energy
//! predictions for batches of molecules through the predict artifact —
//! demonstrating that inference shares the packed fixed-shape data-plane
//! with training and reporting latency/throughput percentiles.
//!
//! The request queue is a Serving-class *session* on a persistent
//! `DataPlane`: sharded LPFHP planning means the first prediction fires
//! after O(shard) host work, admission credits bound how far the plane
//! runs ahead of the device, and every `HostBatch` recycles through the
//! buffer pool when its lease drops after `predict`. The session
//! carries an `Slo` deadline, so the dispatcher classifies every served
//! batch as met/missed and — under overload — sheds predicted-miss
//! batches instead of queueing them unboundedly (a shed batch arrives
//! as an `Err` whose message starts with `"shed:"`; the example counts
//! it as deliberate degradation, not a failure). Session metrics
//! (dispatcher queue wait, credit stalls, shed/met/missed) are reported
//! alongside latency.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_energy -- [requests]
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use molpack::coordinator::{Batcher, DataPlane, JobSpec, PipelineConfig, Slo};
use molpack::datasets::HydroNet;
use molpack::packing::Packer;
use molpack::runtime::Engine;
use molpack::util::stats::summarize;

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    let engine = Engine::load("artifacts")?;
    let state = engine.init_state()?;
    let source = Arc::new(HydroNet::new(requests, 99));
    let batcher = Batcher::new(engine.manifest.batch, engine.manifest.model.r_cut as f32);
    let cfg = PipelineConfig { packer: Packer::Lpfhp, shard_size: 128, ..Default::default() };

    // The request queue is one Serving-class session on the plane, with
    // a dispatcher-wait SLO: generous enough that a healthy in-process
    // run sheds nothing, but every served batch is classified met/missed
    // and a wedged plane degrades by shedding instead of queueing.
    let slo = Slo::deadline(50.0);
    let plane = DataPlane::new(source, batcher, cfg);
    let mut session = plane.open_session(JobSpec::serving().with_credits(4).with_slo(slo));
    println!(
        "serve_energy: {requests} molecules streaming in shards of {} (G={} slots/batch, session #{} qos={}, SLO {:.0} ms)",
        plane.config().shard_size,
        engine.manifest.batch.n_graphs,
        session.id(),
        session.qos().name(),
        slo.deadline_ms,
    );

    let mut latencies = Vec::new();
    let mut batches = 0usize;
    let mut served = 0usize;
    let mut shed_batches = 0usize;
    let mut sq_err = 0.0f64;
    let t_all = Instant::now();
    for lease in session.by_ref() {
        let batch = match lease {
            Ok(b) => b,
            // Deliberate SLO degradation, not a failure: the dispatcher
            // predicted this batch would miss its deadline and shed it.
            Err(e) if e.to_string().starts_with("shed:") => {
                shed_batches += 1;
                continue;
            }
            Err(e) => return Err(e),
        };
        let t0 = Instant::now();
        let energies = engine.predict(&state.params, &batch)?;
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
        batches += 1;
        for (i, (&m, &t)) in batch.graph_mask.iter().zip(&batch.target).enumerate() {
            if m == 1.0 {
                served += 1;
                let e = energies[i] as f64 - t as f64;
                sq_err += e * e;
            }
        }
        // lease drops here: the batch buffer returns to the pool
    }
    let total = t_all.elapsed().as_secs_f64();

    if shed_batches == 0 {
        assert_eq!(served, requests, "every request must be answered exactly once");
    }
    if served == 0 {
        // 0-request invocation: there is no throughput or error to
        // report — dividing by `served` here used to print NaN RMSE and
        // a misleading "0 molecules in 0.0s" rate.
        println!("\nserved 0 molecules (empty request queue) in {total:.2}s — no latency/RMSE to report");
        println!("serve_energy OK");
        return Ok(());
    }

    let s = summarize(&latencies);
    println!(
        "\nserved {served} molecules in {batches} packed batches in {total:.2}s ({:.1} mol/s)",
        served as f64 / total
    );
    println!(
        "batch latency ms: mean {:.2} p50 {:.2} p95 {:.2} max {:.2}",
        s.mean, s.p50, s.p95, s.max
    );
    let waits = session.queue_wait_samples_ms();
    let w = summarize(&waits);
    let m = session.metrics();
    println!(
        "dispatcher queue wait ms: p50 {:.3} p95 {:.3} | assembly {:.1} ms total | credit stalls {}",
        w.p50,
        w.p95,
        m.assembly_time.as_secs_f64() * 1e3,
        m.credit_stalls
    );
    println!(
        "SLO: deadline met {} missed {} (hit rate {:.3}) | shed {} | down-classed {}",
        m.deadline_met,
        m.deadline_missed,
        m.deadline_hit_rate(),
        m.shed,
        m.downclassed
    );
    println!(
        "data-plane buffers allocated: {} (recycled across {batches} batches)",
        plane.buffers_allocated()
    );
    println!(
        "RMSE vs synthetic targets (untrained params, sanity only): {:.3}",
        (sq_err / served as f64).sqrt()
    );
    println!("serve_energy OK");
    Ok(())
}
