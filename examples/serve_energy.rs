//! Serving path: load trained (or initial) parameters and serve energy
//! predictions for batches of molecules through the predict artifact —
//! demonstrating that inference shares the packed fixed-shape path with
//! training and reporting latency/throughput percentiles.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_energy -- [requests]
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use molpack::coordinator::{plan_epoch, Batcher, PipelineConfig};
use molpack::datasets::{HydroNet, MoleculeSource};
use molpack::packing::Packer;
use molpack::runtime::Engine;
use molpack::util::stats::summarize;

fn main() -> Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);

    let engine = Engine::load("artifacts")?;
    let state = engine.init_state()?;
    let source = Arc::new(HydroNet::new(requests, 99));
    let batcher = Batcher::new(engine.manifest.batch, engine.manifest.model.r_cut as f32);
    let cfg = PipelineConfig { packer: Packer::Lpfhp, ..Default::default() };

    // Pack the request queue exactly like the training path.
    let plan = plan_epoch(source.as_ref(), &batcher, &cfg, 0);
    println!(
        "serve_energy: {requests} molecules -> {} packed batches (G={} slots each)",
        plan.len(),
        engine.manifest.batch.n_graphs
    );

    let mut latencies = Vec::new();
    let mut served = 0usize;
    let mut sq_err = 0.0f64;
    let t_all = Instant::now();
    for packs in &plan {
        let batch = batcher.assemble(packs, source.as_ref())?;
        let t0 = Instant::now();
        let energies = engine.predict(&state.params, &batch)?;
        latencies.push(t0.elapsed().as_secs_f64() * 1e3);
        for (i, (&m, &t)) in batch.graph_mask.iter().zip(&batch.target).enumerate() {
            if m == 1.0 {
                served += 1;
                let e = energies[i] as f64 - t as f64;
                sq_err += e * e;
            }
        }
    }
    let total = t_all.elapsed().as_secs_f64();

    let s = summarize(&latencies);
    println!("\nserved {served} molecules in {total:.2}s ({:.1} mol/s)", served as f64 / total);
    println!(
        "batch latency ms: mean {:.2} p50 {:.2} p95 {:.2} max {:.2}",
        s.mean, s.p50, s.p95, s.max
    );
    println!(
        "RMSE vs synthetic targets (untrained params, sanity only): {:.3}",
        (sq_err / served as f64).sqrt()
    );
    assert_eq!(served, requests, "every request must be answered exactly once");
    println!("serve_energy OK");
    Ok(())
}
