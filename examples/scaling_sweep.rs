//! Strong-scaling study (paper Figs. 9/13 + Table 1): evaluate the
//! calibrated performance model across datasets × replica counts ×
//! optimization settings, and compare against the 8×A100 DDP baseline.
//!
//! ```sh
//! cargo run --release --example scaling_sweep
//! ```

use molpack::baseline::{estimate_gpu_epoch, GpuArch};
use molpack::ipu::IpuArch;
use molpack::perfmodel::calibration::paper_profiles;
use molpack::perfmodel::{estimate_epoch, OptFlags, SchNetDims, TrainSetup};
use molpack::util::plot::{line_chart, md_table};

fn main() {
    let arch = IpuArch::bow();
    let gpu = GpuArch::a100();
    let scales = [1usize, 2, 4, 8, 16, 32, 64];

    println!("=== per-epoch seconds (packing, all optimizations) ===\n");
    let mut rows = Vec::new();
    for w in paper_profiles() {
        let mut row = vec![w.name.clone()];
        for &r in &scales {
            let e = estimate_epoch(
                &w,
                &TrainSetup { n_ipus: r, opts: OptFlags::ALL, ..Default::default() },
                &arch,
            );
            row.push(format!("{:.2}", e.epoch_secs));
        }
        let g = estimate_gpu_epoch(&w, &SchNetDims::default(), 8, &gpu);
        row.push(format!("{:.2}", g.epoch_secs));
        rows.push(row);
    }
    println!(
        "{}",
        md_table(&["dataset", "1", "2", "4", "8", "16", "32", "64", "8xA100"], &rows)
    );

    println!("=== throughput curves (graphs/s), packing vs padding ===\n");
    for w in paper_profiles() {
        let x: Vec<f64> = scales.iter().map(|&r| (r as f64).log2()).collect();
        let mut series = Vec::new();
        for (label, packing) in [("packing", true), ("padding", false)] {
            let ys: Vec<f64> = scales
                .iter()
                .map(|&r| {
                    let mut opts = OptFlags::ALL;
                    opts.packing = packing;
                    estimate_epoch(
                        &w,
                        &TrainSetup { n_ipus: r, opts, ..Default::default() },
                        &arch,
                    )
                    .throughput_graphs_per_s
                })
                .collect();
            series.push((label, ys));
        }
        println!(
            "{}",
            line_chart(
                &format!("{} throughput vs log2(IPUs)", w.name),
                &x,
                &series,
                48,
                10
            )
        );
    }

    println!("=== step breakdown at 16 IPUs ===\n");
    let mut rows = Vec::new();
    for w in paper_profiles() {
        let e = estimate_epoch(
            &w,
            &TrainSetup { n_ipus: 16, opts: OptFlags::ALL, ..Default::default() },
            &arch,
        );
        rows.push(vec![
            w.name.clone(),
            format!("{:.0}", e.steps_per_epoch),
            format!("{:.1}", e.graphs_per_step),
            format!("{:.2}ms", e.step_device_secs * 1e3),
            format!("{:.2}ms", e.step_allreduce_secs * 1e3),
            format!("{:.2}ms", e.step_host_secs * 1e3),
        ]);
    }
    println!(
        "{}",
        md_table(
            &["dataset", "steps/epoch", "graphs/step", "device", "allreduce", "host"],
            &rows
        )
    );
    println!("scaling_sweep OK");
}
