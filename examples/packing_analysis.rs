//! Packing deep-dive (paper Fig. 8 + section 4.1): run LPFHP and every
//! baseline packer over the real synthetic datasets, sweep the pack budget
//! s_m, and print efficiency/pack-count tables — including the
//! characteristic non-smooth spikes from the discrete size histograms.
//!
//! ```sh
//! cargo run --release --example packing_analysis
//! ```

use molpack::datasets::PaperDataset;
use molpack::packing::{lower_bound_packs, Packer};
use molpack::util::plot::md_table;

fn main() {
    let sample = 10_000;
    for ds in [PaperDataset::Qm9, PaperDataset::Water4_5m] {
        let src = ds.source((ds.full_len() / sample).max(1), 11);
        let n = src.len().min(sample);
        let sizes: Vec<usize> = (0..n).map(|i| src.n_atoms(i)).collect();
        let max = *sizes.iter().max().unwrap();
        println!(
            "=== {} — {} graphs, sizes {}..{max} ===\n",
            ds.name(),
            sizes.len(),
            sizes.iter().min().unwrap()
        );

        // packer comparison at s_m = max (the paper's base setting)
        let mut rows = Vec::new();
        for p in [
            Packer::Padding,
            Packer::NextFit,
            Packer::FirstFitDecreasing,
            Packer::BestFitDecreasing,
            Packer::Lpfhp,
        ] {
            let t0 = std::time::Instant::now();
            let packing = p.run(&sizes, max, None);
            let dt = t0.elapsed();
            packing.assert_valid(&sizes, None);
            rows.push(vec![
                p.name().to_string(),
                packing.n_packs().to_string(),
                format!("{:.2}%", packing.padding_fraction() * 100.0),
                format!("{:.1}ms", dt.as_secs_f64() * 1e3),
            ]);
        }
        rows.push(vec![
            "volume LB".into(),
            lower_bound_packs(&sizes, max).to_string(),
            "-".into(),
            "-".into(),
        ]);
        println!(
            "{}",
            md_table(&["packer", "packs", "residual padding", "time"], &rows)
        );

        // s_m sweep (Fig. 8) with fine steps to expose the spikes
        let mut rows = Vec::new();
        let mut s_m = max;
        while s_m <= 6 * max {
            let packing = Packer::Lpfhp.run(&sizes, s_m, None);
            rows.push(vec![
                s_m.to_string(),
                format!("{:.2}%", packing.padding_fraction() * 100.0),
                format!("{:.3}", packing.efficiency()),
            ]);
            s_m += (max / 6).max(1);
        }
        println!("{}", md_table(&["s_m", "padding", "efficiency"], &rows));
    }
    println!("packing_analysis OK");
}
