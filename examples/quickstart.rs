//! Quickstart: load the AOT artifacts, run a few real train steps on the
//! PJRT CPU client, then a predict call — the smallest end-to-end tour of
//! the three-layer stack.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use molpack::runtime::{Engine, HostBatch};
use molpack::util::Rng;

/// Hand-rolled demo batch: one random "molecule" per pack with radius-graph
/// edges (the coordinator's batcher does this for real datasets).
fn demo_batch(engine: &Engine, rng: &mut Rng) -> HostBatch {
    let g = engine.manifest.batch;
    let r_cut = engine.manifest.model.r_cut;
    let mut b = HostBatch::empty(&g);
    for p in 0..g.packs_per_batch {
        let n0 = p * g.nodes_per_pack;
        let e0 = p * g.edges_per_pack;
        let na = 20 + rng.range(0, 10);
        // random atoms in a 6 A box
        for i in 0..na {
            b.z[n0 + i] = 1 + rng.range(0, 8) as i32;
            for c in 0..3 {
                b.pos[(n0 + i) * 3 + c] = rng.uniform(0.0, 6.0) as f32;
            }
            b.graph_id[n0 + i] = (p * g.graphs_per_pack) as i32;
            b.node_mask[n0 + i] = 1.0;
        }
        // radius edges within the pack
        let mut k = 0;
        for i in 0..na {
            for j in 0..na {
                if i == j || k >= g.edges_per_pack {
                    continue;
                }
                let dx: f32 = (0..3)
                    .map(|c| {
                        let d = b.pos[(n0 + i) * 3 + c] - b.pos[(n0 + j) * 3 + c];
                        d * d
                    })
                    .sum::<f32>()
                    .sqrt();
                if (dx as f64) < r_cut {
                    b.src[e0 + k] = (n0 + i) as i32;
                    b.dst[e0 + k] = (n0 + j) as i32;
                    b.edge_mask[e0 + k] = 1.0;
                    k += 1;
                }
            }
        }
        // padding edges: self-loops on the pack's dump node
        for e in k..g.edges_per_pack {
            b.src[e0 + e] = (n0 + na) as i32;
            b.dst[e0 + e] = (n0 + na) as i32;
        }
        // synthetic target: 0.1 * sum(z)
        let zsum: i32 = (0..na).map(|i| b.z[n0 + i]).sum();
        b.target[p * g.graphs_per_pack] = 0.1 * zsum as f32;
        b.graph_mask[p * g.graphs_per_pack] = 1.0;
    }
    // hand-built masks: refresh the cached real counts the batcher would
    // normally maintain
    b.recount();
    b
}

fn main() -> Result<()> {
    let engine = Engine::load("artifacts")?;
    println!(
        "loaded artifacts: platform={} params={} batch(N={}, E={}, G={})",
        engine.platform(),
        engine.manifest.param_count,
        engine.manifest.batch.n_nodes,
        engine.manifest.batch.n_edges,
        engine.manifest.batch.n_graphs,
    );

    let mut rng = Rng::new(42);
    let batch = demo_batch(&engine, &mut rng);
    let mut state = engine.init_state()?;

    println!("training 20 steps on a synthetic batch:");
    for step in 1..=20 {
        let loss = engine.train_step(&mut state, &batch)?;
        if step % 5 == 0 || step == 1 {
            println!("  step {step:>3}  loss {loss:.6}");
        }
    }

    let energies = engine.predict(&state.params, &batch)?;
    let real: Vec<(usize, f32)> = batch
        .graph_mask
        .iter()
        .enumerate()
        .filter(|(_, &m)| m == 1.0)
        .map(|(i, _)| (i, energies[i]))
        .collect();
    println!("predicted energies (real graphs): {real:?}");
    println!(
        "targets                          : {:?}",
        batch
            .graph_mask
            .iter()
            .enumerate()
            .filter(|(_, &m)| m == 1.0)
            .map(|(i, _)| (i, batch.target[i]))
            .collect::<Vec<_>>()
    );

    let s = engine.stats();
    println!(
        "engine stats: steps={} marshal={:.1}ms/step execute={:.1}ms/step readback={:.1}ms/step",
        s.steps,
        1e3 * s.marshal_secs / s.steps as f64,
        1e3 * s.execute_secs / s.steps as f64,
        1e3 * s.readback_secs / s.steps as f64,
    );
    println!("quickstart OK");
    Ok(())
}
