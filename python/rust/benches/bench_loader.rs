fn main() {}
