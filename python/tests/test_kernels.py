"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps the shape space (edge counts, block sizes, feature and
basis dims) and asserts allclose for both the forward values and the
hand-written backward kernels (via jax.grad of a scalarized output).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import filter_messages, rbf_expand, scatter_add, ref
from compile.kernels.scatter_add import gather_rows

SETTINGS = dict(deadline=None, max_examples=15)


def rand(key, *shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# softplus / ssp (paper Eqs. 10-11)
# ---------------------------------------------------------------------------


@given(st.floats(-100.0, 100.0))
@settings(**SETTINGS)
def test_softplus_opt_matches_naive(x):
    a = float(ref.softplus_naive(jnp.float32(x)))
    b = float(ref.softplus_opt(jnp.float32(x)))
    assert abs(a - b) < 1e-5


def test_softplus_opt_extremes_stable():
    for x in [-1e4, -50.0, 0.0, 50.0, 1e4]:
        v = float(ref.softplus_opt(jnp.float32(x)))
        assert np.isfinite(v)
        assert v >= 0.0
    # saturates to identity for large x
    assert abs(float(ref.softplus_opt(jnp.float32(100.0))) - 100.0) < 1e-5


def test_ssp_zero_is_zero():
    # shifted softplus is 0 at 0: softplus(0) = log 2
    assert abs(float(ref.ssp(jnp.float32(0.0)))) < 1e-7


# ---------------------------------------------------------------------------
# RBF expansion (paper Eq. 2)
# ---------------------------------------------------------------------------


@given(
    blocks=st.integers(1, 6),
    block_e=st.sampled_from([8, 16, 32]),
    n_rbf=st.integers(2, 32),
    r_cut=st.floats(2.0, 10.0),
    seed=st.integers(0, 2**31),
)
@settings(**SETTINGS)
def test_rbf_matches_ref(blocks, block_e, n_rbf, r_cut, seed):
    e = blocks * block_e
    d = rand(seed, e, lo=0.0, hi=r_cut + 1.0)
    out = rbf_expand(d, n_rbf=n_rbf, r_cut=r_cut, block_e=block_e)
    want = ref.rbf_expand(d, n_rbf, r_cut)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


@given(
    block_e=st.sampled_from([8, 32]),
    n_rbf=st.integers(3, 25),
    seed=st.integers(0, 2**31),
)
@settings(**SETTINGS)
def test_rbf_grad_matches_ref(block_e, n_rbf, seed):
    e = 2 * block_e
    d = rand(seed, e, lo=0.1, hi=6.0)

    def f_kernel(d):
        return jnp.sum(jnp.sin(rbf_expand(d, n_rbf=n_rbf, r_cut=6.0, block_e=block_e)))

    def f_ref(d):
        return jnp.sum(jnp.sin(ref.rbf_expand(d, n_rbf, 6.0)))

    g1 = jax.grad(f_kernel)(d)
    g2 = jax.grad(f_ref)(d)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4, rtol=1e-4)


def test_rbf_peak_at_center():
    # d exactly on a Gaussian center gives 1.0 in that column.
    n_rbf, r_cut = 25, 6.0
    dmu = r_cut / (n_rbf - 1)
    d = jnp.full((8,), 3 * dmu, jnp.float32)
    out = np.asarray(rbf_expand(d, n_rbf=n_rbf, r_cut=r_cut, block_e=8))
    np.testing.assert_allclose(out[:, 3], 1.0, atol=1e-6)
    # far-off Gaussians may underflow to exactly 0 in f32
    assert (out <= 1.0 + 1e-6).all() and (out >= 0.0).all()


def test_rbf_rejects_indivisible_edges():
    with pytest.raises(AssertionError):
        rbf_expand(jnp.ones((100,)), n_rbf=8, r_cut=6.0, block_e=64)


# ---------------------------------------------------------------------------
# Fused filter MLP
# ---------------------------------------------------------------------------


@given(
    blocks=st.integers(1, 4),
    block_e=st.sampled_from([8, 16]),
    k=st.integers(2, 25),
    f_dim=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31),
)
@settings(**SETTINGS)
def test_filter_matches_ref(blocks, block_e, k, f_dim, seed):
    e = blocks * block_e
    rbf = rand(seed, e, k, lo=0.0, hi=1.0)
    hsrc = rand(seed + 1, e, f_dim)
    cut = rand(seed + 2, e, lo=0.0, hi=1.0)
    w1 = rand(seed + 3, k, f_dim)
    b1 = rand(seed + 4, f_dim)
    w2 = rand(seed + 5, f_dim, f_dim)
    b2 = rand(seed + 6, f_dim)
    out = filter_messages(rbf, hsrc, cut, w1, b1, w2, b2, block_e=block_e)
    want = ref.filter_messages(rbf, hsrc, cut, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-5)


@given(seed=st.integers(0, 2**31))
@settings(deadline=None, max_examples=8)
def test_filter_grads_match_ref(seed):
    e, k, f_dim, block_e = 32, 7, 8, 16
    args = (
        rand(seed, e, k, lo=0.0, hi=1.0),
        rand(seed + 1, e, f_dim),
        rand(seed + 2, e, lo=0.0, hi=1.0),
        rand(seed + 3, k, f_dim),
        rand(seed + 4, f_dim),
        rand(seed + 5, f_dim, f_dim),
        rand(seed + 6, f_dim),
    )

    def f_kernel(*a):
        return jnp.sum(jnp.tanh(filter_messages(*a, block_e=block_e)))

    def f_ref(*a):
        return jnp.sum(jnp.tanh(ref.filter_messages(*a)))

    g1 = jax.grad(f_kernel, argnums=tuple(range(7)))(*args)
    g2 = jax.grad(f_ref, argnums=tuple(range(7)))(*args)
    for i, (a, b) in enumerate(zip(g1, g2)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4,
            err_msg=f"grad argnum {i}",
        )


# ---------------------------------------------------------------------------
# Scatter-add (one-hot matmul) + gather backward
# ---------------------------------------------------------------------------


@given(
    blocks=st.integers(1, 4),
    block_e=st.sampled_from([8, 16, 32]),
    n_nodes=st.integers(1, 64),
    f_dim=st.sampled_from([4, 8, 32]),
    seed=st.integers(0, 2**31),
)
@settings(**SETTINGS)
def test_scatter_matches_ref(blocks, block_e, n_nodes, f_dim, seed):
    e = blocks * block_e
    msg = rand(seed, e, f_dim)
    dst = jax.random.randint(jax.random.PRNGKey(seed + 1), (e,), 0, n_nodes)
    out = scatter_add(msg, dst, n_nodes=n_nodes, block_e=block_e)
    want = ref.scatter_add(msg, dst, n_nodes)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)


def test_scatter_all_to_one_node():
    e, f_dim, n = 64, 8, 10
    msg = jnp.ones((e, f_dim))
    dst = jnp.full((e,), 3, jnp.int32)
    out = np.asarray(scatter_add(msg, dst, n_nodes=n, block_e=16))
    np.testing.assert_allclose(out[3], e * np.ones(f_dim), atol=1e-4)
    assert np.abs(np.delete(out, 3, axis=0)).max() == 0.0


@given(seed=st.integers(0, 2**31))
@settings(deadline=None, max_examples=10)
def test_scatter_grad_is_gather(seed):
    e, f_dim, n, block_e = 32, 8, 12, 16
    msg = rand(seed, e, f_dim)
    dst = jax.random.randint(jax.random.PRNGKey(seed + 1), (e,), 0, n)
    w = rand(seed + 2, n, f_dim)

    def f_kernel(m):
        return jnp.sum(w * scatter_add(m, dst, n_nodes=n, block_e=block_e))

    g = jax.grad(f_kernel)(msg)
    np.testing.assert_allclose(np.asarray(g), np.asarray(w)[np.asarray(dst)], atol=1e-5)


@given(
    n=st.integers(1, 40),
    f_dim=st.sampled_from([4, 16]),
    seed=st.integers(0, 2**31),
)
@settings(**SETTINGS)
def test_gather_rows_matches_ref(n, f_dim, seed):
    e, block_e = 32, 16
    table = rand(seed, n, f_dim)
    idx = jax.random.randint(jax.random.PRNGKey(seed + 1), (e,), 0, n)
    out = gather_rows(table, idx, block_e=block_e)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table)[np.asarray(idx)])


def test_scatter_gather_roundtrip_identity():
    # scatter with a permutation then gather back is the identity.
    n = f_dim = 16
    perm = np.random.default_rng(0).permutation(n)
    msg = np.asarray(rand(0, n, f_dim))
    out = np.asarray(scatter_add(jnp.asarray(msg), jnp.asarray(perm), n_nodes=n, block_e=16))
    np.testing.assert_allclose(out[perm], msg, atol=1e-6)
