"""AOT pipeline checks: HLO text artifacts + manifest consistency.

These are the compile-path contract tests for the Rust side: the manifest's
declared shapes must match what the lowered HLO expects, and the HLO must
be text-parseable (the xla_extension 0.5.1 interchange constraint).
"""

import json
import os
import struct

import jax
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.config import DEFAULT, BatchConfig, CompileConfig, ModelConfig

TINY = CompileConfig(
    model=ModelConfig(hidden=8, n_rbf=4, n_interactions=1, r_cut=6.0, z_max=16),
    batch=BatchConfig(
        packs_per_batch=1, nodes_per_pack=16, edges_per_pack=64, graphs_per_pack=2
    ),
)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(TINY, out)
    return out, manifest


def test_manifest_files_exist(built):
    out, manifest = built
    for art in manifest["artifacts"].values():
        assert os.path.getsize(os.path.join(out, art["file"])) > 0
    assert os.path.exists(os.path.join(out, "init_params.bin"))
    assert os.path.exists(os.path.join(out, "manifest.json"))


def test_init_params_size_matches_count(built):
    out, manifest = built
    n = os.path.getsize(os.path.join(out, "init_params.bin"))
    assert n == 4 * manifest["param_count"]


def test_param_layout_is_contiguous(built):
    _, manifest = built
    off = 0
    for entry in manifest["param_layout"]:
        assert entry["offset"] == off
        assert entry["size"] == int(np.prod(entry["shape"])) if entry["shape"] else 1
        off += entry["size"]
    assert off == manifest["param_count"]


def test_hlo_text_is_parseable(built):
    """Round-trip the emitted text through the XLA HLO parser."""
    out, manifest = built
    for art in manifest["artifacts"].values():
        text = open(os.path.join(out, art["file"])).read()
        assert "ENTRY" in text and "HloModule" in text
        # number of top-level parameters must match the declared inputs
        n_params = text.count("parameter(")
        assert n_params >= len(art["inputs"])


def test_train_step_input_specs_match_model(built):
    _, manifest = built
    args = model.train_step_example_args(TINY)
    specs = manifest["artifacts"]["train_step"]["inputs"]
    assert len(specs) == len(args)
    for s, a in zip(specs, args):
        assert tuple(s["shape"]) == a.shape
        assert s["dtype"] == a.dtype.name
    names = manifest["artifacts"]["train_step"]["input_names"]
    assert names[:4] == ["params", "adam_m", "adam_v", "step"]
    assert tuple(names[4:]) == model.BATCH_TRAIN_FIELDS


def test_hlo_text_roundtrips_through_parser(built):
    """Parse the emitted text with the XLA HLO parser -- the exact entry
    point the Rust runtime uses (HloModuleProto::from_text_file). Execution
    numerics of the parsed module are covered by the Rust integration test
    `runtime::tests` + examples/quickstart, which run on the same PJRT CPU
    backend."""
    out, manifest = built
    for key, art in manifest["artifacts"].items():
        text = open(os.path.join(out, art["file"])).read()
        mod = xc._xla.hlo_module_from_text(text)
        roundtrip = mod.to_string()
        assert "ENTRY" in roundtrip, key
        # parameter declarations survive the roundtrip with their shapes
        for spec in art["inputs"]:
            if spec["shape"]:
                dims = ",".join(str(d) for d in spec["shape"])
                token = f"[{dims}]"
                assert token in roundtrip, f"{key}: missing shape {token}"


def test_predict_agrees_with_forward_reference(built):
    """The lowered predict function computes the same energies as the
    un-jitted reference forward pass on a random (valid-format) batch."""
    _, manifest = built
    rng = np.random.default_rng(0)
    args = []
    for spec in manifest["artifacts"]["predict"]["inputs"]:
        shape = tuple(spec["shape"])
        if spec["dtype"] == "int32":
            args.append(rng.integers(0, 2, shape).astype(np.int32))
        else:
            args.append(rng.uniform(0.0, 1.0, shape).astype(np.float32))
    got = np.asarray(jax.jit(model.make_predict(TINY))(*args))
    p = model.unflatten(TINY, args[0])
    want = np.asarray(model.forward(TINY, p, *args[1:]))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_default_config_param_count_is_stable():
    # Guard: Rust artifacts embed this count; changing the architecture
    # must be a deliberate act that also regenerates artifacts.
    assert model.param_count(DEFAULT) == 57873
