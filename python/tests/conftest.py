import os
import sys

# Allow `pytest tests/` from python/ and `pytest python/tests/` from repo root.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PY = os.path.dirname(_HERE)
if _PY not in sys.path:
    sys.path.insert(0, _PY)
