"""L2 correctness: SchNet model invariants on the packed batch format."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import BatchConfig, CompileConfig, ModelConfig

# Small config so each jit is fast on CPU.
CFG = CompileConfig(
    model=ModelConfig(hidden=16, n_rbf=8, n_interactions=2, r_cut=6.0, z_max=16),
    batch=BatchConfig(
        packs_per_batch=2, nodes_per_pack=32, edges_per_pack=128, graphs_per_pack=4
    ),
)


def make_batch(cfg=CFG, seed=0, atoms_per_pack=(12, 20)):
    """Synthetic packed batch: one molecule per pack, radius-graph edges."""
    rng = np.random.default_rng(seed)
    b = cfg.batch
    N, E, G = b.n_nodes, b.n_edges, b.n_graphs
    z = np.zeros(N, np.int32)
    pos = np.zeros((N, 3), np.float32)
    gid = np.full(N, G - 1, np.int32)  # dump slot for padding
    nmask = np.zeros(N, np.float32)
    src = np.zeros(E, np.int32)
    dst = np.zeros(E, np.int32)
    emask = np.zeros(E, np.float32)
    tgt = np.zeros(G, np.float32)
    gmask = np.zeros(G, np.float32)
    for p in range(b.packs_per_batch):
        na = atoms_per_pack[p % len(atoms_per_pack)]
        n0, e0 = p * b.nodes_per_pack, p * b.edges_per_pack
        z[n0 : n0 + na] = rng.integers(1, 9, na)
        pos[n0 : n0 + na] = rng.uniform(0, 5.0, (na, 3)).astype(np.float32)
        gid[n0 : n0 + na] = p * b.graphs_per_pack
        nmask[n0 : n0 + na] = 1
        k = 0
        for i in range(na):
            for j in range(na):
                dij = np.linalg.norm(pos[n0 + i] - pos[n0 + j])
                if i != j and dij < cfg.model.r_cut and k < b.edges_per_pack:
                    src[e0 + k], dst[e0 + k], emask[e0 + k] = n0 + i, n0 + j, 1
                    k += 1
        # padding edges: dump self-loops within the pack
        src[e0 + k : e0 + b.edges_per_pack] = n0 + na
        dst[e0 + k : e0 + b.edges_per_pack] = n0 + na
        tgt[p * b.graphs_per_pack] = 0.1 * z[n0 : n0 + na].sum()
        gmask[p * b.graphs_per_pack] = 1
    names = model.BATCH_TRAIN_FIELDS
    arrs = (z, pos, src, dst, emask, gid, nmask, tgt, gmask)
    return dict(zip(names, [jnp.asarray(a) for a in arrs]))


def fwd_energies(cfg, flat, batch):
    p = model.unflatten(cfg, flat)
    return model.forward(cfg, p, *[batch[f] for f in model.BATCH_FWD_FIELDS])


# ---------------------------------------------------------------------------
# Parameter plumbing
# ---------------------------------------------------------------------------


def test_flatten_unflatten_roundtrip():
    params = model.init_params(CFG)
    flat = model.flatten(CFG, params)
    assert flat.shape == (model.param_count(CFG),)
    back = model.unflatten(CFG, flat)
    for name, _ in model.param_specs(CFG):
        np.testing.assert_array_equal(np.asarray(params[name]), np.asarray(back[name]))


def test_param_count_matches_specs():
    total = sum(int(np.prod(s)) for _, s in model.param_specs(CFG))
    assert model.param_count(CFG) == total


def test_init_deterministic_in_seed():
    a = model.flatten(CFG, model.init_params(CFG))
    b = model.flatten(CFG, model.init_params(CFG))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = model.flatten(
        CFG, model.init_params(dataclasses.replace(CFG, seed=CFG.seed + 1))
    )
    assert not np.array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# Forward invariants
# ---------------------------------------------------------------------------


def test_forward_shapes_and_finite():
    batch = make_batch()
    flat = model.flatten(CFG, model.init_params(CFG))
    e = fwd_energies(CFG, flat, batch)
    assert e.shape == (CFG.batch.n_graphs,)
    assert np.isfinite(np.asarray(e)).all()


def test_padding_nodes_do_not_leak():
    """Garbage in the padded region must not change real-graph energies."""
    batch = make_batch()
    flat = model.flatten(CFG, model.init_params(CFG))
    e1 = np.asarray(fwd_energies(CFG, flat, batch))

    poisoned = dict(batch)
    pos = np.asarray(batch["pos"]).copy()
    nmask = np.asarray(batch["node_mask"])
    pos[nmask == 0] = 777.0  # far away so no spurious edges anyway
    z = np.asarray(batch["z"]).copy()
    z[nmask == 0] = 9
    poisoned["pos"] = jnp.asarray(pos)
    poisoned["z"] = jnp.asarray(z)
    e2 = np.asarray(fwd_energies(CFG, flat, poisoned))

    real = np.asarray(batch["graph_mask"]) == 1
    np.testing.assert_allclose(e1[real], e2[real], atol=1e-5)


def test_pack_independence():
    """Graphs packed together must not interact (no cross-contamination).

    Energy of pack-0's molecule is identical whether pack 1 holds a
    molecule or is empty -- the packing analogue of the paper's claim that
    packs are disconnected components.
    """
    flat = model.flatten(CFG, model.init_params(CFG))
    full = make_batch(atoms_per_pack=(12, 20))
    solo = make_batch(atoms_per_pack=(12, 0))
    e_full = np.asarray(fwd_energies(CFG, flat, full))
    e_solo = np.asarray(fwd_energies(CFG, flat, solo))
    np.testing.assert_allclose(e_full[0], e_solo[0], atol=1e-5)


def test_atom_permutation_invariance():
    """Relabeling atoms within a molecule leaves its energy unchanged."""
    batch = make_batch(atoms_per_pack=(12, 20))
    flat = model.flatten(CFG, model.init_params(CFG))
    e1 = np.asarray(fwd_energies(CFG, flat, batch))

    rng = np.random.default_rng(3)
    na = 12
    perm = rng.permutation(na)  # permute atoms of pack 0's molecule
    inv = np.argsort(perm)
    z = np.asarray(batch["z"]).copy()
    pos = np.asarray(batch["pos"]).copy()
    z[:na] = z[:na][perm]
    pos[:na] = pos[:na][perm]
    src = np.asarray(batch["src"]).copy()
    dst = np.asarray(batch["dst"]).copy()
    sel = (src < na) & (np.asarray(batch["edge_mask"]) == 1)
    src[sel] = inv[src[sel]]
    dst[sel] = inv[dst[sel]]
    b2 = dict(batch)
    b2.update(
        z=jnp.asarray(z), pos=jnp.asarray(pos), src=jnp.asarray(src), dst=jnp.asarray(dst)
    )
    e2 = np.asarray(fwd_energies(CFG, flat, b2))
    np.testing.assert_allclose(e1[0], e2[0], atol=1e-4)


def test_translation_invariance():
    """Energies depend on distances only: rigid translation changes nothing."""
    batch = make_batch()
    flat = model.flatten(CFG, model.init_params(CFG))
    e1 = np.asarray(fwd_energies(CFG, flat, batch))
    b2 = dict(batch)
    b2["pos"] = batch["pos"] + jnp.asarray([10.0, -5.0, 3.0])
    e2 = np.asarray(fwd_energies(CFG, flat, b2))
    np.testing.assert_allclose(e1, e2, atol=1e-4)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def test_train_step_reduces_loss():
    batch = make_batch()
    flat = model.flatten(CFG, model.init_params(CFG))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    step = jnp.float32(0)
    ts = jax.jit(model.make_train_step(CFG))
    args = [batch[f] for f in model.BATCH_TRAIN_FIELDS]
    losses = []
    for _ in range(15):
        flat, m, v, step, loss = ts(flat, m, v, step, *args)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses
    assert float(step) == 15.0


def test_train_step_grad_only_touches_params():
    """Pad-masked batches give finite loss/grads (no NaN from d=0 edges)."""
    batch = make_batch(atoms_per_pack=(0, 0))  # fully padded batch
    flat = model.flatten(CFG, model.init_params(CFG))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    ts = jax.jit(model.make_train_step(CFG))
    args = [batch[f] for f in model.BATCH_TRAIN_FIELDS]
    flat2, m2, v2, step2, loss = ts(flat, m, v, jnp.float32(0), *args)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(flat2)).all()


def test_grad_step_matches_autodiff_of_loss():
    """The data-parallel artifact's gradient is exactly grad(loss)."""
    batch = make_batch()
    flat = model.flatten(CFG, model.init_params(CFG))
    args = [batch[f] for f in model.BATCH_TRAIN_FIELDS]
    loss, grad = jax.jit(model.make_grad_step(CFG))(flat, *args)
    want_loss = model.loss_fn(CFG, flat, batch)
    want_grad = jax.grad(lambda w: model.loss_fn(CFG, w, batch))(flat)
    assert abs(float(loss) - float(want_loss)) < 1e-4 * max(1.0, abs(float(want_loss)))
    np.testing.assert_allclose(
        np.asarray(grad), np.asarray(want_grad), atol=1e-5, rtol=1e-4
    )


def test_grad_step_plus_manual_adam_tracks_train_step():
    """One fused train_step == one grad_step + a hand-rolled Adam update
    (the contract the Rust optim::Adam relies on)."""
    batch = make_batch()
    o = CFG.opt
    flat = model.flatten(CFG, model.init_params(CFG))
    args = [batch[f] for f in model.BATCH_TRAIN_FIELDS]

    new_flat, *_ = jax.jit(model.make_train_step(CFG))(
        flat, jnp.zeros_like(flat), jnp.zeros_like(flat), jnp.float32(0), *args
    )

    _, grad = jax.jit(model.make_grad_step(CFG))(flat, *args)
    m = (1.0 - o.beta1) * grad
    v = (1.0 - o.beta2) * grad * grad
    m_hat = m / (1.0 - o.beta1)
    v_hat = v / (1.0 - o.beta2)
    manual = flat - o.lr * m_hat / (jnp.sqrt(v_hat) + o.eps)
    np.testing.assert_allclose(np.asarray(new_flat), np.asarray(manual), atol=1e-6)


def test_loss_fn_matches_mse_definition():
    batch = make_batch()
    flat = model.flatten(CFG, model.init_params(CFG))
    pred = np.asarray(fwd_energies(CFG, flat, batch))
    gm = np.asarray(batch["graph_mask"])
    tgt = np.asarray(batch["target"])
    want = float((((pred - tgt) * gm) ** 2).sum() / gm.sum())
    got = float(model.loss_fn(CFG, flat, batch))
    assert abs(got - want) < 1e-4 * max(1.0, abs(want))
