"""Pallas kernels: fused continuous-filter message generation (fwd + bwd).

This is the MXU hot spot of SchNet's interaction block. The paper keeps the
filter network resident in IPU tile SRAM and streams edges through it; the
TPU adaptation (DESIGN.md section 3) keeps W1[K,F] and W2[F,F] resident in
VMEM across all grid steps (constant index maps) and streams (block_e, K)
RBF tiles through the matmul chain:

    f   = ssp(rbf @ W1 + b1)        # MXU
    f   = ssp(f @ W2 + b2)          # MXU
    msg = h_src * f * cut           # VPU modulation

ssp is the paper's Eq. 11 optimized softplus shifted by log 2 -- branch
free, so it vectorizes (no select/where on the hot path).

The backward pass is a second Pallas kernel (``jax.custom_vjp``) that
*rematerializes* the two activations instead of spilling them (L2 perf
choice: recompute-in-VMEM beats an HBM round-trip for (E, F) tensors).
Weight/bias gradients use the same VMEM-resident accumulator pattern as
scatter_add.py: their output BlockSpecs map every grid step to the same
block and are zeroed at step 0.

VMEM per grid step (f32, block_e=128, K=25, F=64): inputs+weights+out
~130KB, bwd accumulators ~22KB -- far under a TPU core's ~16MB VMEM
(DESIGN.md section 8 has the full table).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG2 = 0.6931471805599453


def _ssp(x):
    # Paper Eq. 11 shifted: log1p(exp(-|x|)) + max(x,0) - log 2.
    return jnp.log1p(jnp.exp(-jnp.abs(x))) + jnp.maximum(x, 0.0) - LOG2


def _sigmoid(x):
    # d ssp / dx = sigmoid(x); branch-free stable form.
    return jnp.exp(-jnp.log1p(jnp.exp(-jnp.abs(x))) + jnp.minimum(x, 0.0))


def _fwd_kernel(rbf_ref, hsrc_ref, cut_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    f = _ssp(rbf_ref[...] @ w1_ref[...] + b1_ref[...][None, :])
    f = _ssp(f @ w2_ref[...] + b2_ref[...][None, :])
    o_ref[...] = hsrc_ref[...] * f * cut_ref[...][:, None]


def _bwd_kernel(
    rbf_ref, hsrc_ref, cut_ref, w1_ref, b1_ref, w2_ref, b2_ref, g_ref,
    grbf_ref, ghsrc_ref, gcut_ref, gw1_ref, gb1_ref, gw2_ref, gb2_ref,
):
    # Zero the cross-block weight-gradient accumulators once.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        gw1_ref[...] = jnp.zeros_like(gw1_ref)
        gb1_ref[...] = jnp.zeros_like(gb1_ref)
        gw2_ref[...] = jnp.zeros_like(gw2_ref)
        gb2_ref[...] = jnp.zeros_like(gb2_ref)

    rbf, hsrc, cut = rbf_ref[...], hsrc_ref[...], cut_ref[...]
    w1, b1, w2, b2 = w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...]
    g = g_ref[...]

    # Rematerialize forward activations in VMEM.
    z1 = rbf @ w1 + b1[None, :]
    a1 = _ssp(z1)
    z2 = a1 @ w2 + b2[None, :]
    a2 = _ssp(z2)

    gh = g * a2 * cut[:, None]                 # d/d h_src
    gf = g * hsrc * cut[:, None]               # d/d a2
    gcut_ref[...] = jnp.sum(g * hsrc * a2, axis=1)

    gz2 = gf * _sigmoid(z2)
    ghsrc_ref[...] = gh
    gw2_ref[...] += a1.T @ gz2
    gb2_ref[...] += jnp.sum(gz2, axis=0)

    gz1 = (gz2 @ w2.T) * _sigmoid(z1)
    grbf_ref[...] = gz1 @ w1.T
    gw1_ref[...] += rbf.T @ gz1
    gb1_ref[...] += jnp.sum(gz1, axis=0)


def _specs(block_e, k, f_dim):
    """Input BlockSpecs shared by fwd and bwd (bwd appends the cotangent)."""
    return [
        pl.BlockSpec((block_e, k), lambda i: (i, 0)),        # rbf
        pl.BlockSpec((block_e, f_dim), lambda i: (i, 0)),    # h_src
        pl.BlockSpec((block_e,), lambda i: (i,)),            # cut
        pl.BlockSpec((k, f_dim), lambda i: (0, 0)),          # w1 (resident)
        pl.BlockSpec((f_dim,), lambda i: (0,)),              # b1 (resident)
        pl.BlockSpec((f_dim, f_dim), lambda i: (0, 0)),      # w2 (resident)
        pl.BlockSpec((f_dim,), lambda i: (0,)),              # b2 (resident)
    ]


def _check(rbf, h_src, cut, w1, b1, w2, b2, block_e):
    e, k = rbf.shape
    f_dim = w1.shape[1]
    assert e % block_e == 0, f"edge count {e} not a multiple of {block_e}"
    assert h_src.shape == (e, f_dim) and cut.shape == (e,)
    assert w1.shape == (k, f_dim) and b1.shape == (f_dim,)
    assert w2.shape == (f_dim, f_dim) and b2.shape == (f_dim,)
    return e, k, f_dim


def _call_fwd(rbf, h_src, cut, w1, b1, w2, b2, block_e):
    e, k, f_dim = _check(rbf, h_src, cut, w1, b1, w2, b2, block_e)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(e // block_e,),
        in_specs=_specs(block_e, k, f_dim),
        out_specs=pl.BlockSpec((block_e, f_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, f_dim), rbf.dtype),
        interpret=True,
    )(rbf, h_src, cut, w1, b1, w2, b2)


def _call_bwd(rbf, h_src, cut, w1, b1, w2, b2, g, block_e):
    e, k, f_dim = _check(rbf, h_src, cut, w1, b1, w2, b2, block_e)
    dt = rbf.dtype
    sds = jax.ShapeDtypeStruct
    return pl.pallas_call(
        _bwd_kernel,
        grid=(e // block_e,),
        in_specs=_specs(block_e, k, f_dim)
        + [pl.BlockSpec((block_e, f_dim), lambda i: (i, 0))],  # g
        out_specs=[
            pl.BlockSpec((block_e, k), lambda i: (i, 0)),      # g_rbf
            pl.BlockSpec((block_e, f_dim), lambda i: (i, 0)),  # g_hsrc
            pl.BlockSpec((block_e,), lambda i: (i,)),          # g_cut
            pl.BlockSpec((k, f_dim), lambda i: (0, 0)),        # g_w1 (acc)
            pl.BlockSpec((f_dim,), lambda i: (0,)),            # g_b1 (acc)
            pl.BlockSpec((f_dim, f_dim), lambda i: (0, 0)),    # g_w2 (acc)
            pl.BlockSpec((f_dim,), lambda i: (0,)),            # g_b2 (acc)
        ],
        out_shape=[
            sds((e, k), dt),
            sds((e, f_dim), dt),
            sds((e,), dt),
            sds((k, f_dim), dt),
            sds((f_dim,), dt),
            sds((f_dim, f_dim), dt),
            sds((f_dim,), dt),
        ],
        interpret=True,
    )(rbf, h_src, cut, w1, b1, w2, b2, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def _filter(rbf, h_src, cut, w1, b1, w2, b2, block_e):
    return _call_fwd(rbf, h_src, cut, w1, b1, w2, b2, block_e)


def _filter_fwd(rbf, h_src, cut, w1, b1, w2, b2, block_e):
    out = _call_fwd(rbf, h_src, cut, w1, b1, w2, b2, block_e)
    return out, (rbf, h_src, cut, w1, b1, w2, b2)


def _filter_bwd(block_e, res, g):
    return _call_bwd(*res, g, block_e)


_filter.defvjp(_filter_fwd, _filter_bwd)


def filter_messages(rbf, h_src, cut, w1, b1, w2, b2, *, block_e: int = 128):
    """Fused filter-MLP + modulation.

    rbf: [E, K], h_src: [E, F], cut: [E], w1: [K, F], w2: [F, F].
    Returns msg: [E, F]. E must divide by block_e. Differentiable in all
    tensor arguments via the hand-written backward kernel.
    """
    return _filter(rbf, h_src, cut, w1, b1, w2, b2, block_e)
