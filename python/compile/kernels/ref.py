"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has a reference implementation here
written with plain ``jax.numpy`` ops. ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts allclose between kernel
and oracle; the oracle is also what the L2 model tests compare against.
"""

import jax.numpy as jnp

LOG2 = 0.6931471805599453


def softplus_naive(x):
    """PyTorch-style conditional softplus (paper Eq. 10, beta=1, tau=20)."""
    return jnp.where(x <= 20.0, jnp.log1p(jnp.exp(jnp.minimum(x, 20.0))), x)


def softplus_opt(x):
    """Paper Eq. 11: branch-free numerically stable softplus.

    softplus(x) = log(1 + exp(-|x|)) + max(x, 0)
    """
    return jnp.log1p(jnp.exp(-jnp.abs(x))) + jnp.maximum(x, 0.0)


def ssp(x):
    """Shifted softplus, SchNet's activation: softplus(x) - log 2."""
    return softplus_opt(x) - LOG2


def rbf_expand(d, n_rbf, r_cut):
    """Gaussian radial basis expansion (paper Eq. 2).

    Centers on a uniform grid [0, r_cut] with spacing dmu = r_cut/(K-1),
    gamma = 1/dmu^2. d: [...] -> [..., n_rbf].
    """
    dmu = r_cut / (n_rbf - 1)
    gamma = 1.0 / (dmu * dmu)
    mu = jnp.arange(n_rbf, dtype=d.dtype) * dmu
    diff = d[..., None] - mu
    return jnp.exp(-gamma * diff * diff)


def cosine_cutoff(d, r_cut):
    """Behler-style cosine cutoff: smooth decay of influence to 0 at r_cut."""
    c = 0.5 * (jnp.cos(jnp.pi * d / r_cut) + 1.0)
    return jnp.where(d < r_cut, c, 0.0)


def filter_messages(rbf, h_src, cut, w1, b1, w2, b2):
    """Continuous-filter message generation (reference for filter_mlp.py).

    W(e) = ssp(ssp(rbf @ w1 + b1) @ w2 + b2)   -- the 'filter network'
    msg  = h_src * W(e) * cut                   -- per-edge modulation
    """
    f = ssp(rbf @ w1 + b1)
    f = ssp(f @ w2 + b2)
    return h_src * f * cut[..., None]


def scatter_add(messages, dst, n_nodes):
    """Segment-sum aggregation (reference for scatter_add.py).

    out[n] = sum over edges e with dst[e] == n of messages[e].
    Matches paper Eq. 6 with A = 0.
    """
    out = jnp.zeros((n_nodes, messages.shape[-1]), dtype=messages.dtype)
    return out.at[dst].add(messages)


def gather_rows(table, idx):
    """Row gather (paper Eq. 5)."""
    return table[idx]
