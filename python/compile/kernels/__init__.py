"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts).

All kernels run under ``interpret=True`` so the lowered HLO executes on the
CPU PJRT client the Rust runtime uses. ``ref.py`` is the pure-jnp oracle.
"""

from . import ref
from .filter_mlp import filter_messages
from .rbf import rbf_expand
from .scatter_add import scatter_add

__all__ = ["ref", "filter_messages", "rbf_expand", "scatter_add"]
