"""Pallas kernels: scatter-add aggregation as one-hot matmul (fwd + bwd).

The paper (sections 4.2.1-4.2.2) vectorizes scatter on IPU tiles and plans
its partitioning. A mechanical port would serialize read-modify-write per
edge; the TPU rethink (DESIGN.md section 3) converts the scatter into a
*dense MXU matmul* per edge block:

    out += onehot(dst_block)^T @ msg_block      # (N, block_e) @ (block_e, F)

The output BlockSpec maps every grid step to the same (N, F) block, so the
accumulator stays in VMEM for the whole sweep over edge blocks (zeroed at
step 0 with pl.when). Padding edges point at a dump node with zeroed
messages, exactly like the paper's pack padding.

This mirrors the planner's I-partitioning: each grid step is one
I-partition of the scatter; the cross-step reduction is the
'scatter reduce' term of paper Eq. 9 -- free here because the accumulator
never leaves VMEM.

Backward of scatter-add is a *gather* (paper Eq. 5): g_msg[e] = g[dst[e]],
implemented as its own Pallas kernel with the cotangent table resident in
VMEM, and wired up with jax.custom_vjp (dst is an integer input, so its
cotangent is float0).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _scatter_kernel(msg_ref, dst_ref, o_ref, *, n_nodes: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    msg = msg_ref[...]                       # (block_e, F)
    dst = dst_ref[...]                       # (block_e,) int32
    node_ids = jax.lax.broadcasted_iota(jnp.int32, (n_nodes, dst.shape[0]), 0)
    onehot_t = (node_ids == dst[None, :]).astype(msg.dtype)  # (N, block_e)
    o_ref[...] += onehot_t @ msg


def _gather_kernel(table_ref, idx_ref, o_ref):
    # Row gather with the full table resident (constant index map).
    o_ref[...] = table_ref[...][idx_ref[...]]


def _call_scatter(messages, dst, n_nodes, block_e):
    e, f_dim = messages.shape
    assert e % block_e == 0, f"edge count {e} not a multiple of {block_e}"
    assert dst.shape == (e,)
    return pl.pallas_call(
        functools.partial(_scatter_kernel, n_nodes=n_nodes),
        grid=(e // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, f_dim), lambda i: (i, 0)),
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n_nodes, f_dim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, f_dim), messages.dtype),
        interpret=True,
    )(messages, dst.astype(jnp.int32))


def gather_rows(table, idx, *, block_e: int = 128):
    """Row gather out[e] = table[idx[e]] -- the scatter-add backward."""
    n, f_dim = table.shape
    (e,) = idx.shape
    assert e % block_e == 0, f"edge count {e} not a multiple of {block_e}"
    return pl.pallas_call(
        _gather_kernel,
        grid=(e // block_e,),
        in_specs=[
            pl.BlockSpec((n, f_dim), lambda i: (0, 0)),   # table resident
            pl.BlockSpec((block_e,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_e, f_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, f_dim), table.dtype),
        interpret=True,
    )(table, idx.astype(jnp.int32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _scatter(messages, dst, n_nodes, block_e):
    return _call_scatter(messages, dst, n_nodes, block_e)


def _scatter_fwd(messages, dst, n_nodes, block_e):
    return _call_scatter(messages, dst, n_nodes, block_e), dst


def _scatter_bwd(n_nodes, block_e, dst, g):
    g_msg = gather_rows(g, dst, block_e=block_e)
    return g_msg, np.zeros(dst.shape, jax.dtypes.float0)


_scatter.defvjp(_scatter_fwd, _scatter_bwd)


def scatter_add(messages, dst, *, n_nodes: int, block_e: int = 128):
    """out[n] = sum_{e : dst[e]==n} messages[e].

    messages: [E, F], dst: [E] int32 in [0, n_nodes). Returns [n_nodes, F].
    E must divide by block_e. Differentiable in ``messages``.
    """
    return _scatter(messages, dst, n_nodes, block_e)
