"""Pallas kernels: Gaussian RBF expansion of edge distances (paper Eq. 2).

TPU adaptation (DESIGN.md section 3): pure VPU elementwise work. The edge
dimension is tiled into ``block_e`` chunks; each grid step keeps a
(block_e,) distance slice and the (n_rbf,) center grid resident in VMEM and
materializes a (block_e, n_rbf) tile. Grid parameters are compile-time
constants, so there is no parameter traffic at all.

``pallas_call`` has no automatic autodiff, so the backward pass is a
hand-written Pallas kernel wired up with ``jax.custom_vjp`` -- mirroring
how the paper's Poplar codelets are scheduled for both directions.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
under the Rust runtime. On a real TPU the BlockSpecs are the schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _centers(n_rbf: int, r_cut: float, dtype):
    dmu = r_cut / (n_rbf - 1)
    gamma = 1.0 / (dmu * dmu)
    return jnp.arange(n_rbf, dtype=dtype) * dmu, gamma


def _fwd_kernel(d_ref, o_ref, *, n_rbf: int, r_cut: float):
    mu, gamma = _centers(n_rbf, r_cut, o_ref.dtype)
    diff = d_ref[...][:, None] - mu[None, :]
    o_ref[...] = jnp.exp(-gamma * diff * diff)


def _bwd_kernel(d_ref, g_ref, o_ref, *, n_rbf: int, r_cut: float):
    # d(exp(-gamma diff^2))/dd = -2 gamma diff exp(-gamma diff^2)
    mu, gamma = _centers(n_rbf, r_cut, g_ref.dtype)
    diff = d_ref[...][:, None] - mu[None, :]
    e = jnp.exp(-gamma * diff * diff)
    o_ref[...] = jnp.sum(g_ref[...] * (-2.0 * gamma) * diff * e, axis=1)


def _call_fwd(d, n_rbf, r_cut, block_e):
    (e,) = d.shape
    assert e % block_e == 0, f"edge count {e} not a multiple of {block_e}"
    return pl.pallas_call(
        functools.partial(_fwd_kernel, n_rbf=n_rbf, r_cut=r_cut),
        grid=(e // block_e,),
        in_specs=[pl.BlockSpec((block_e,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_e, n_rbf), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, n_rbf), d.dtype),
        interpret=True,
    )(d)


def _call_bwd(d, g, n_rbf, r_cut, block_e):
    (e,) = d.shape
    return pl.pallas_call(
        functools.partial(_bwd_kernel, n_rbf=n_rbf, r_cut=r_cut),
        grid=(e // block_e,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),
            pl.BlockSpec((block_e, n_rbf), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), d.dtype),
        interpret=True,
    )(d, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def _rbf(d, n_rbf, r_cut, block_e):
    return _call_fwd(d, n_rbf, r_cut, block_e)


def _rbf_fwd(d, n_rbf, r_cut, block_e):
    return _call_fwd(d, n_rbf, r_cut, block_e), d


def _rbf_bwd(n_rbf, r_cut, block_e, d, g):
    return (_call_bwd(d, g, n_rbf, r_cut, block_e),)


_rbf.defvjp(_rbf_fwd, _rbf_bwd)


def rbf_expand(d, *, n_rbf: int, r_cut: float, block_e: int = 128):
    """Expand distances d: [E] -> [E, n_rbf]. E must divide by block_e."""
    return _rbf(d, n_rbf, r_cut, block_e)
