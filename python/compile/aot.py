"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the published ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under --out, default ../artifacts):
  train_step.hlo.txt   fused fwd+bwd+Adam over the packed batch
  predict.hlo.txt      forward-only energies
  init_params.bin      flat f32 LE initial parameter vector
  manifest.json        config + shapes + parameter layout for the Rust side

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

import argparse
import json
import os
import struct

import jax
from jax._src.lib import xla_client as xc

from . import model
from .config import DEFAULT, CompileConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _tensor_spec(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": s.dtype.name}


def _param_layout(cfg: CompileConfig):
    layout, off = [], 0
    for name, shape in model.param_specs(cfg):
        size = 1
        for d in shape:
            size *= d
        layout.append(
            {"name": name, "shape": list(shape), "offset": off, "size": size}
        )
        off += size
    return layout, off


def build(cfg: CompileConfig, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)

    # --- train_step -------------------------------------------------------
    train_args = model.train_step_example_args(cfg)
    lowered = jax.jit(model.make_train_step(cfg)).lower(*train_args)
    train_hlo = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "train_step.hlo.txt"), "w") as f:
        f.write(train_hlo)

    # --- predict ----------------------------------------------------------
    pred_args = model.predict_example_args(cfg)
    lowered = jax.jit(model.make_predict(cfg)).lower(*pred_args)
    pred_hlo = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "predict.hlo.txt"), "w") as f:
        f.write(pred_hlo)

    # --- grad_step (data-parallel path: loss + gradient, no optimizer) ----
    grad_args = model.grad_step_example_args(cfg)
    lowered = jax.jit(model.make_grad_step(cfg)).lower(*grad_args)
    grad_hlo = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "grad_step.hlo.txt"), "w") as f:
        f.write(grad_hlo)

    # --- initial parameters -----------------------------------------------
    flat = model.flatten(cfg, model.init_params(cfg))
    data = bytes()
    import numpy as np

    data = np.asarray(flat, dtype="<f4").tobytes()
    with open(os.path.join(out_dir, "init_params.bin"), "wb") as f:
        f.write(data)

    # --- manifest -----------------------------------------------------------
    layout, total = _param_layout(cfg)
    b = cfg.batch
    manifest = {
        "version": 1,
        "config": cfg.to_dict(),
        "param_count": total,
        "param_layout": layout,
        "batch": {
            "n_nodes": b.n_nodes,
            "n_edges": b.n_edges,
            "n_graphs": b.n_graphs,
            "packs_per_batch": b.packs_per_batch,
            "nodes_per_pack": b.nodes_per_pack,
            "edges_per_pack": b.edges_per_pack,
            "graphs_per_pack": b.graphs_per_pack,
        },
        "artifacts": {
            "train_step": {
                "file": "train_step.hlo.txt",
                "inputs": [_tensor_spec(s) for s in train_args],
                "input_names": ["params", "adam_m", "adam_v", "step"]
                + list(model.BATCH_TRAIN_FIELDS),
                "outputs": ["params", "adam_m", "adam_v", "step", "loss"],
            },
            "predict": {
                "file": "predict.hlo.txt",
                "inputs": [_tensor_spec(s) for s in pred_args],
                "input_names": ["params"] + list(model.BATCH_FWD_FIELDS),
                "outputs": ["energies"],
            },
            "grad_step": {
                "file": "grad_step.hlo.txt",
                "inputs": [_tensor_spec(s) for s in grad_args],
                "input_names": ["params"] + list(model.BATCH_TRAIN_FIELDS),
                "outputs": ["loss", "grad"],
            },
        },
        "init_params": {"file": "init_params.bin", "dtype": "f32-le"},
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    cfg = DEFAULT
    manifest = build(cfg, args.out)
    sizes = {
        k: os.path.getsize(os.path.join(args.out, v["file"]))
        for k, v in manifest["artifacts"].items()
    }
    print(
        f"AOT done: params={manifest['param_count']} "
        f"batch(N={manifest['batch']['n_nodes']}, "
        f"E={manifest['batch']['n_edges']}, "
        f"G={manifest['batch']['n_graphs']}) "
        f"hlo bytes={sizes}"
    )


if __name__ == "__main__":
    main()
