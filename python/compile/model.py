"""L2: SchNet forward/backward in JAX, calling the L1 Pallas kernels.

The model operates on the fixed-shape packed batch format of DESIGN.md
section 5 and exposes two entry points that ``aot.py`` lowers to HLO text:

* ``train_step(params, m, v, step, *batch) -> (params', m', v', loss)`` --
  one fused forward + backward + Adam update over a *flat f32 parameter
  vector* (single tensor), so the Rust side marshals exactly four state
  tensors plus the batch.
* ``predict(params, *batch_fwd) -> energies`` -- inference for the serving
  example.

Parameter layout is defined by ``param_specs`` and serialized into the
manifest so Rust can inspect/checkpoint parameters by name.
"""

import functools
import math

import jax
import jax.numpy as jnp

from .config import CompileConfig
from .kernels import filter_messages, rbf_expand, scatter_add
from .kernels.ref import cosine_cutoff, ssp

# ---------------------------------------------------------------------------
# Parameter layout (flat vector <-> named tensors)
# ---------------------------------------------------------------------------


def param_specs(cfg: CompileConfig):
    """Ordered (name, shape) list defining the flat parameter layout."""
    m = cfg.model
    f, k, rh = m.hidden, m.n_rbf, m.readout_hidden
    specs = [("embedding", (m.z_max, f)), ("atomref", (m.z_max,))]
    for t in range(m.n_interactions):
        specs += [
            (f"int{t}.w_in", (f, f)),
            (f"int{t}.filter.w1", (k, f)),
            (f"int{t}.filter.b1", (f,)),
            (f"int{t}.filter.w2", (f, f)),
            (f"int{t}.filter.b2", (f,)),
            (f"int{t}.out.w1", (f, f)),
            (f"int{t}.out.b1", (f,)),
            (f"int{t}.out.w2", (f, f)),
            (f"int{t}.out.b2", (f,)),
        ]
    specs += [
        ("readout.w1", (f, rh)),
        ("readout.b1", (rh,)),
        ("readout.w2", (rh, 1)),
        ("readout.b2", (1,)),
    ]
    return specs


def param_count(cfg: CompileConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def unflatten(cfg: CompileConfig, flat):
    """Flat f32 vector -> dict of named tensors (pure slicing, fuses away)."""
    out, off = {}, 0
    for name, shape in param_specs(cfg):
        size = 1
        for d in shape:
            size *= d
        out[name] = jax.lax.dynamic_slice(flat, (off,), (size,)).reshape(shape)
        off += size
    return out


def flatten(cfg: CompileConfig, params) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_specs(cfg)]
    )


def init_params(cfg: CompileConfig, key=None):
    """Xavier-uniform weights, zero biases, zero atomref."""
    if key is None:
        key = jax.random.PRNGKey(cfg.seed)
    params = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".b1", ".b2")) or name == "atomref":
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "embedding":
            params[name] = 0.1 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in, fan_out = shape[0], shape[-1]
            lim = (6.0 / (fan_in + fan_out)) ** 0.5
            params[name] = jax.random.uniform(
                sub, shape, jnp.float32, -lim, lim
            )
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

BATCH_FWD_FIELDS = (
    "z",          # [N] i32
    "pos",        # [N,3] f32
    "src",        # [E] i32
    "dst",        # [E] i32
    "edge_mask",  # [E] f32
    "graph_id",   # [N] i32
    "node_mask",  # [N] f32
)
BATCH_TRAIN_FIELDS = BATCH_FWD_FIELDS + (
    "target",      # [G] f32
    "graph_mask",  # [G] f32
)


def forward(cfg: CompileConfig, p, z, pos, src, dst, edge_mask, graph_id, node_mask):
    """Packed-batch SchNet forward -> per-graph energies [G]."""
    m = cfg.model
    n_graphs = cfg.batch.n_graphs

    # Atom embeddings (gather, paper Eq. 5).
    h = p["embedding"][z]                                       # [N, F]

    # Edge geometry. Padding edges are (dump, dump) self-loops; masked.
    rvec = pos[src] - pos[dst]                                  # [E, 3]
    d2 = jnp.sum(rvec * rvec, axis=-1)
    # Guard sqrt(0) for padding self-loops (grad of sqrt at 0 is inf).
    d = jnp.sqrt(jnp.maximum(d2, 1e-12))                        # [E]
    # Edge-block size: 128 lanes when the edge budget allows, else the
    # largest power-of-two divisor (small test configs).
    block_e = math.gcd(128, d.shape[0])
    rbf = rbf_expand(d, n_rbf=m.n_rbf, r_cut=m.r_cut, block_e=block_e)  # L1
    cut = cosine_cutoff(d, m.r_cut) * edge_mask                 # [E]

    # Interaction blocks (paper Eq. 3).
    for t in range(m.n_interactions):
        x = h @ p[f"int{t}.w_in"]                               # [N, F]
        msg = filter_messages(                                  # L1 kernel
            rbf, x[src], cut,
            p[f"int{t}.filter.w1"], p[f"int{t}.filter.b1"],
            p[f"int{t}.filter.w2"], p[f"int{t}.filter.b2"],
            block_e=block_e,
        )
        agg = scatter_add(msg, dst, n_nodes=h.shape[0], block_e=block_e)  # L1
        v = ssp(agg @ p[f"int{t}.out.w1"] + p[f"int{t}.out.b1"])
        h = h + (v @ p[f"int{t}.out.w2"] + p[f"int{t}.out.b2"])

    # Atom-wise readout to scalar contributions.
    a = ssp(h @ p["readout.w1"] + p["readout.b1"])
    e_atom = (a @ p["readout.w2"] + p["readout.b2"])[:, 0]      # [N]
    e_atom = (e_atom + p["atomref"][z]) * node_mask

    # Pool per molecule: segment-sum over graph ids (pad nodes masked).
    energies = jnp.zeros((n_graphs,), e_atom.dtype).at[graph_id].add(e_atom)
    return energies


def loss_fn(cfg: CompileConfig, flat, batch):
    p = unflatten(cfg, flat)
    pred = forward(cfg, p, *[batch[f] for f in BATCH_FWD_FIELDS])
    err = (pred - batch["target"]) * batch["graph_mask"]
    denom = jnp.maximum(jnp.sum(batch["graph_mask"]), 1.0)
    return jnp.sum(err * err) / denom


# ---------------------------------------------------------------------------
# Training step (Adam in-graph)
# ---------------------------------------------------------------------------


def make_train_step(cfg: CompileConfig):
    o = cfg.opt

    def train_step(flat, m_state, v_state, step, *batch_tensors):
        batch = dict(zip(BATCH_TRAIN_FIELDS, batch_tensors))
        loss, grad = jax.value_and_grad(lambda w: loss_fn(cfg, w, batch))(flat)
        step = step + 1.0
        m_new = o.beta1 * m_state + (1.0 - o.beta1) * grad
        v_new = o.beta2 * v_state + (1.0 - o.beta2) * grad * grad
        m_hat = m_new / (1.0 - o.beta1**step)
        v_hat = v_new / (1.0 - o.beta2**step)
        flat_new = flat - o.lr * m_hat / (jnp.sqrt(v_hat) + o.eps)
        return flat_new, m_new, v_new, step, loss

    return train_step


def make_grad_step(cfg: CompileConfig):
    """Loss + flat gradient only (no optimizer): the artifact behind the
    Rust-side data-parallel path, where the coordinator all-reduces
    gradients across replicas (merged, like paper section 4.3) and applies
    Adam natively."""

    def grad_step(flat, *batch_tensors):
        batch = dict(zip(BATCH_TRAIN_FIELDS, batch_tensors))
        loss, grad = jax.value_and_grad(lambda w: loss_fn(cfg, w, batch))(flat)
        return loss, grad

    return grad_step


def make_predict(cfg: CompileConfig):
    def predict(flat, *batch_tensors):
        p = unflatten(cfg, flat)
        return forward(cfg, p, *batch_tensors)

    return predict


# ---------------------------------------------------------------------------
# Example-arg builders for AOT lowering
# ---------------------------------------------------------------------------


def batch_shape_structs(cfg: CompileConfig, train: bool = True):
    b = cfg.batch
    n, e, g = b.n_nodes, b.n_edges, b.n_graphs
    sds = jax.ShapeDtypeStruct
    shapes = {
        "z": sds((n,), jnp.int32),
        "pos": sds((n, 3), jnp.float32),
        "src": sds((e,), jnp.int32),
        "dst": sds((e,), jnp.int32),
        "edge_mask": sds((e,), jnp.float32),
        "graph_id": sds((n,), jnp.int32),
        "node_mask": sds((n,), jnp.float32),
        "target": sds((g,), jnp.float32),
        "graph_mask": sds((g,), jnp.float32),
    }
    fields = BATCH_TRAIN_FIELDS if train else BATCH_FWD_FIELDS
    return [shapes[f] for f in fields]


def train_step_example_args(cfg: CompileConfig):
    p = param_count(cfg)
    sds = jax.ShapeDtypeStruct
    state = [
        sds((p,), jnp.float32),  # params
        sds((p,), jnp.float32),  # adam m
        sds((p,), jnp.float32),  # adam v
        sds((), jnp.float32),    # step counter
    ]
    return state + batch_shape_structs(cfg, train=True)


def predict_example_args(cfg: CompileConfig):
    p = param_count(cfg)
    return [jax.ShapeDtypeStruct((p,), jnp.float32)] + batch_shape_structs(
        cfg, train=False
    )


def grad_step_example_args(cfg: CompileConfig):
    p = param_count(cfg)
    return [jax.ShapeDtypeStruct((p,), jnp.float32)] + batch_shape_structs(
        cfg, train=True
    )
