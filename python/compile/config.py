"""Shared model/batch configuration for the L1/L2 compile path.

The same numbers are serialized into ``artifacts/manifest.json`` so that the
Rust coordinator (L3) assembles batches with exactly the shapes the AOT
artifacts were compiled for. Fixed shapes are the whole point: like the
IPU's ahead-of-time Poplar compilation in the paper, the PJRT executable
is specialized to one (N, E, G) batch geometry, which is what makes batch
*packing* (vs padding) matter.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """SchNet hyperparameters (paper section 5.1.2 defaults, scaled)."""

    hidden: int = 64          # paper default 100; 64 keeps CPU steps fast
    n_rbf: int = 25           # paper: uniform grid of 25 Gaussians
    n_interactions: int = 3   # paper default 4
    r_cut: float = 6.0        # Angstrom radial cutoff (Eq. 1)
    z_max: int = 16           # atomic-number vocabulary (H..F + padding 0)

    @property
    def readout_hidden(self) -> int:
        return max(self.hidden // 2, 8)


@dataclass(frozen=True)
class BatchConfig:
    """Fixed-shape packed batch geometry (DESIGN.md section 5).

    A batch is ``packs_per_batch`` packs, each with a node budget of
    ``nodes_per_pack`` and an edge budget of ``edges_per_pack``. The
    flattened tensors have N/E/G leading dims below.
    """

    packs_per_batch: int = 4
    nodes_per_pack: int = 96
    edges_per_pack: int = 1152   # k_max(12) * nodes_per_pack
    graphs_per_pack: int = 12    # >= nodes_per_pack / min_graph_size seen

    @property
    def n_nodes(self) -> int:
        return self.packs_per_batch * self.nodes_per_pack

    @property
    def n_edges(self) -> int:
        return self.packs_per_batch * self.edges_per_pack

    @property
    def n_graphs(self) -> int:
        return self.packs_per_batch * self.graphs_per_pack


@dataclass(frozen=True)
class OptimizerConfig:
    """Adam, paper section 5.1.2."""

    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8


@dataclass(frozen=True)
class CompileConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    batch: BatchConfig = field(default_factory=BatchConfig)
    opt: OptimizerConfig = field(default_factory=OptimizerConfig)
    seed: int = 0

    def to_dict(self) -> dict:
        return asdict(self)


DEFAULT = CompileConfig()
