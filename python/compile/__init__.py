"""Build-time compile path (L1 kernels + L2 model + AOT lowering).

Never imported at runtime: ``make artifacts`` runs ``compile.aot`` once and
the Rust binary is self-contained afterwards.
"""
