# molpack build/verify entry points.
#
#   make artifacts    AOT-lower the JAX model (L2+L1) to HLO text under
#                     rust/artifacts — required once before `train`,
#                     `serve`, the examples, and the artifact-gated tests
#                     (they skip gracefully without it).
#   make check        the CI gate: formatting, clippy (warnings are
#                     errors), the project lint gate (`molpack tidy`),
#                     the test suite (including the persistence
#                     round-trip / stale-cache / truncation / mutation-
#                     fuzz tests in datasets::persist, datasets::prepared,
#                     and coordinator::dataplane), the CI-sized race
#                     explorer, and bench compilation.
#   make lint         the tidy static-analysis pass alone (zero findings
#                     or explicit `// tidy: allow(...)` invariants).
#   make race         deterministic dispatcher race explorer at CI depth
#                     (~10k seeded interleavings; a failure prints a
#                     seed — replay it with MOLPACK_RACE_SEED=<seed>).
#   make test         tests only.
#   make bench-smoke  CI-sized acceptance sections of bench_pipeline:
#                     assembly cold-vs-warm (>= 2x warm-epoch bar,
#                     BENCH_assembly.json) and the fresh-process persist
#                     section (>= 1.5x warm-from-disk epoch-1 bar,
#                     bitwise-identical stream, BENCH_persist.json).

.PHONY: check fmt clippy lint test race bench-build bench-smoke artifacts

check: fmt clippy lint test race bench-build

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

lint:
	cargo run -q -- tidy

test:
	cargo test -q

race:
	MOLPACK_RACE_SCHEDULES=10000 cargo test -q --test race

# Benches must at least compile in CI even though they only run on demand.
bench-build:
	cargo bench --no-run

bench-smoke:
	cargo bench --bench bench_pipeline -- --assembly-only --graphs 4000 --out BENCH_assembly.json
	cargo bench --bench bench_pipeline -- --persist-only --graphs 4000 --persist-out BENCH_persist.json

artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts
