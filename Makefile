# molpack build/verify entry points.
#
#   make artifacts    AOT-lower the JAX model (L2+L1) to HLO text under
#                     rust/artifacts — required once before `train`,
#                     `serve`, the examples, and the artifact-gated tests
#                     (they skip gracefully without it).
#   make check        the CI gate: formatting, clippy (warnings are
#                     errors), the project lint gate (`molpack tidy`),
#                     the test suite (including the persistence
#                     round-trip / stale-cache / truncation / mutation-
#                     fuzz tests in datasets::persist, datasets::prepared,
#                     and coordinator::dataplane), the CI-sized race
#                     explorer, and bench compilation.
#   make lint         the tidy static-analysis pass alone (zero findings
#                     or explicit `// tidy: allow(...)` invariants).
#   make race         deterministic dispatcher race explorer at CI depth
#                     (~10k seeded interleavings; a failure prints a
#                     seed — replay it with MOLPACK_RACE_SEED=<seed>).
#   make test         tests only.
#   make bench-smoke  CI-sized acceptance sections of bench_pipeline:
#                     assembly cold-vs-warm (>= 2x warm-epoch bar,
#                     BENCH_assembly.json), the fresh-process persist
#                     section (>= 1.5x warm-from-disk epoch-1 bar,
#                     bitwise-identical stream, BENCH_persist.json), the
#                     zero-copy mapped-load section (>= 1.2x mapped
#                     over owned, page-sharing RSS check, BENCH_mmap.json),
#                     the SLO overload section (`make slo`), and the
#                     multi-plane fleet sim (stream equivalence,
#                     >= 1.15x overlapped-collective bar, elastic
#                     join/leave, BENCH_fleet.json), then the chaos
#                     sweep (`make chaos`).
#   make slo          SLO-guarded serving overload: one Serving session
#                     at ~2x its sustainable rate — unguarded queue-wait
#                     p95 must diverge quarter over quarter, a guarded
#                     session must shed (> 0) with served p95 under the
#                     deadline, and coalesced request packs must reach
#                     >= 0.8x the whole-mix training LPFHP fill
#                     (BENCH_slo.json).
#   make chaos        seeded fault-injection sweep: 5 deterministic
#                     chaos schedules through the fleet watchdog
#                     (stall/crash/slow-drain/open-fail/collective-fail/
#                     damaged-cache), asserting detection, force-leave
#                     recovery, gradient equivalence to the single-plane
#                     reference, and bit-identical replay
#                     (BENCH_chaos.json). A failing seed replays with
#                     `-- fleet --chaos --schedules 1 --chaos-seed <s>`.
#   make bench-check  the perf ledger gate: bench-smoke, then `molpack
#                     benchdiff` of each fresh snapshot against the
#                     committed baselines in BENCH_history/ — fails on
#                     any guarded metric regressing beyond 20% or
#                     vanishing from the snapshot.
#   make bench-record refresh the BENCH_history/ baselines from a fresh
#                     bench-smoke run, record `make lint` / `make race`
#                     gate wall-times into BENCH_history/gates.json, and
#                     file the per-PR trajectory snapshot under
#                     BENCH_history/trajectory/<short-sha>/ (run on a
#                     quiet machine; commit the result).

.PHONY: check fmt clippy lint test race chaos slo bench-build bench-smoke bench-check bench-record artifacts

check: fmt clippy lint test race bench-build

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

lint:
	cargo run -q -- tidy

test:
	cargo test -q

race:
	MOLPACK_RACE_SCHEDULES=10000 cargo test -q --test race

# Deterministic chaos sweep: every invariant is asserted inside the
# driver; the snapshot's chaos_virtual_secs is virtual-clock time, so
# it is machine-independent and the ledger guards it tightly.
chaos:
	cargo run --release -q -- fleet --chaos --schedules 5 --graphs 480 --epochs 3 --out BENCH_chaos.json

# SLO overload acceptance: divergence, shedding, and coalescing bars are
# asserted inside the bench; the deterministic pack-fill rates land in
# BENCH_slo.json for the ledger.
slo:
	cargo bench --bench bench_pipeline -- --slo-only --graphs 4000 --slo-out BENCH_slo.json

# Benches must at least compile in CI even though they only run on demand.
bench-build:
	cargo bench --no-run

bench-smoke:
	cargo bench --bench bench_pipeline -- --assembly-only --graphs 4000 --out BENCH_assembly.json
	cargo bench --bench bench_pipeline -- --persist-only --graphs 4000 --persist-out BENCH_persist.json
	cargo bench --bench bench_pipeline -- --mmap-only --graphs 4000 --mmap-out BENCH_mmap.json
	cargo bench --bench bench_pipeline -- --widen-only
	$(MAKE) slo
	cargo run --release -q -- fleet --replicas 3 --graphs 480 --epochs 3 --out BENCH_fleet.json
	$(MAKE) chaos

# Perf ledger gate: fresh smoke snapshots vs the committed baselines.
# Tolerance 0.20 = a guarded metric may be up to 20% worse before
# failing (wall-clock metrics are noisy across CI machines; the hard
# acceptance bars — 2x/1.5x/1.2x/1.15x/0.8x — are asserted inside the
# benches themselves, this gate catches slower drift and vanished
# metrics).
bench-check: bench-smoke
	cargo run -q -- benchdiff --baseline BENCH_history/BENCH_assembly.json --current BENCH_assembly.json --tolerance 0.20
	cargo run -q -- benchdiff --baseline BENCH_history/BENCH_persist.json --current BENCH_persist.json --tolerance 0.20
	cargo run -q -- benchdiff --baseline BENCH_history/BENCH_mmap.json --current BENCH_mmap.json --tolerance 0.20
	cargo run -q -- benchdiff --baseline BENCH_history/BENCH_slo.json --current BENCH_slo.json --tolerance 0.20
	cargo run -q -- benchdiff --baseline BENCH_history/BENCH_fleet.json --current BENCH_fleet.json --tolerance 0.20
	cargo run -q -- benchdiff --baseline BENCH_history/BENCH_chaos.json --current BENCH_chaos.json --tolerance 0.20

# Refresh the committed baselines (run on a quiet machine, then commit
# BENCH_history/). Also times the lint and race gates so gate cost is
# part of the ledger, and files a per-PR trajectory snapshot of all six
# bench JSONs under BENCH_history/trajectory/<short-sha>/ so regressions
# can be bisected against the ledger after the fact.
bench-record: bench-smoke
	mkdir -p BENCH_history
	cp BENCH_assembly.json BENCH_persist.json BENCH_mmap.json BENCH_slo.json BENCH_fleet.json BENCH_chaos.json BENCH_history/
	t0=$$(date +%s%N); $(MAKE) lint >/dev/null; t1=$$(date +%s%N); \
	$(MAKE) race >/dev/null; t2=$$(date +%s%N); \
	{ printf '{\n  "gates": {\n'; \
	  awk -v a=$$t0 -v b=$$t1 -v c=$$t2 \
	    'BEGIN{printf "    \"lint_secs\": %.3f,\n    \"race_secs\": %.3f\n", (b-a)/1e9, (c-b)/1e9}'; \
	  printf '  }\n}\n'; } > BENCH_history/gates.json
	sha=$$(git rev-parse --short HEAD) && \
	mkdir -p BENCH_history/trajectory/$$sha && \
	cp BENCH_assembly.json BENCH_persist.json BENCH_mmap.json BENCH_slo.json BENCH_fleet.json BENCH_chaos.json \
	  BENCH_history/gates.json BENCH_history/trajectory/$$sha/
	@echo "baselines + gate timings + trajectory snapshot recorded into BENCH_history/ — commit them"

artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts
