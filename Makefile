# molpack build/verify entry points.
#
#   make artifacts   AOT-lower the JAX model (L2+L1) to HLO text under
#                    rust/artifacts — required once before `train`,
#                    `serve`, the examples, and the artifact-gated tests
#                    (they skip gracefully without it).
#   make check       the CI gate: formatting, clippy (warnings are
#                    errors), and the test suite.
#   make test        tests only.

.PHONY: check fmt clippy test artifacts

check: fmt clippy test

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

test:
	cargo test -q

artifacts:
	cd python && python3 -m compile.aot --out ../rust/artifacts
